"""Vectorized tiering (batched lane) vs the scalar TieringHook.

Three layers, strongest first:

1. **Golden-input decision identity** — a recording shim captures the
   exact per-window inputs the scalar hook consumed on the pinned
   ``migrate_interference`` run (completed-request deltas, migration
   budgets, restricted bits) and replays them through
   :class:`~repro.memsim.batched.tiering.VectorTiering`.  The vector
   twin's window log must equal ``tests/data/migrate_trace_goldens.json``
   field for field: same state machine, different substrate.
2. **Lane equivalence** — re-simulated (fluid) tiering grids stay within
   the pinned bandwidth tolerance of the scalar DES, with zero lane
   fallbacks.
3. **Telemetry** — batched ``record_windows`` jobs emit the scalar
   window-record schema, tiering block included, and ``--trace`` payloads
   are schema-identical across lanes.
"""

from __future__ import annotations

import dataclasses
import json
import os

import numpy as np
import pytest

from repro.core.controller import Phase
from repro.memsim.batched.stacking import BatchGroup, plan_cell
from repro.memsim.batched.tiering import build_tiering
from repro.memsim.sweep import run_sweep
from repro.scenarios import plan, run_scenario
from repro.tiering.hook import TieringHook, TieringSpec

DATA = os.path.join(os.path.dirname(__file__), "data")

_GOLDEN_KEYS = ("promoted", "demoted", "enqueued", "deferred",
                "backlog_pages", "migrated_bytes")


# ---------------------------------------------------------------------------
# 1. Golden-input decision identity.
# ---------------------------------------------------------------------------


class _RecordingHook(TieringHook):
    """Scalar hook that records its per-window inputs before acting."""

    def __init__(self, spec) -> None:
        super().__init__(spec)
        self.inputs = []

    def on_window(self, sim):
        completed = sim._stat_completed
        deltas = {
            w.name: c - m
            for w, c, m in zip(sim.workloads, completed, self._stat_mark)
        }
        budgets = self._budgets(sim)
        dec = self._latest_decisions(sim)
        restricted = (
            None if dec is None
            else {t: d.phase == Phase.RESTRICTED for t, d in dec.items()}
        )
        self.inputs.append((
            deltas,
            None if budgets is None else dict(budgets),
            restricted,
        ))
        return super().on_window(sim)


class _RecordingSpec(TieringSpec):
    """Spec whose built hooks register themselves for later inspection."""

    hooks = []  # class-level: run_sweep builds the hook out of our hands

    def build(self):
        hook = _RecordingHook(self)
        _RecordingSpec.hooks.append(hook)
        return hook


def _recording_copy(spec: TieringSpec) -> _RecordingSpec:
    return _RecordingSpec(**{
        f.name: getattr(spec, f.name) for f in dataclasses.fields(TieringSpec)
    })


@pytest.fixture(scope="module")
def golden():
    with open(os.path.join(DATA, "migrate_trace_goldens.json")) as f:
        return json.load(f)


def test_vector_tiering_replays_goldens_exactly(golden):
    """Fed the scalar run's own window inputs, VectorTiering's decisions
    (promotions, demotions, deferrals, retirement accounting) must equal
    the pinned golden traces field for field."""
    ((_, _, jobs),) = plan("migrate_interference", golden["overrides"])
    for variant, blob in golden["variants"].items():
        job = jobs[blob["job"]]
        assert job.tiering is not None, variant

        # Scalar run with the recording shim: capture the exact inputs.
        _RecordingSpec.hooks.clear()
        rec_job = dataclasses.replace(job, tiering=_recording_copy(job.tiering))
        run_sweep([rec_job], lane="scalar")
        (hook,) = _RecordingSpec.hooks
        assert len(hook.inputs) == len(blob["windows"]), variant

        # Replay them through the vector twin (one-cell group).
        group = BatchGroup([(0, plan_cell(job))])
        vt = build_tiering(group)
        assert vt is not None
        w_names = group.plans[0].export["w_names"]
        slow_names = vt.tier_names[0][1:]
        frac_live = group.tier_frac.copy()
        effmlp_live = group.effmlp.copy()
        fire = np.array([True])
        for k, (deltas, budgets, restricted) in enumerate(hook.inputs):
            ins_w = np.array([[float(deltas.get(nm, 0)) for nm in w_names]])
            has_b = np.array([budgets is not None])
            has_d = np.array([restricted is not None])
            b_row = np.array([[
                float((budgets or {}).get(nm, 0)) for nm in slow_names
            ]])
            r_row = np.array([[
                bool((restricted or {}).get(nm, False)) for nm in slow_names
            ]])
            vt.step(fire, ins_w, b_row, r_row, has_b, has_d,
                    float(k + 1) * group.window_ns, frac_live, effmlp_live)

        log = vt.window_log[0]
        assert len(log) == len(blob["windows"]), variant
        for got, want in zip(log, blob["windows"]):
            assert got["window"] == want["window"], variant
            for key in _GOLDEN_KEYS:
                assert got[key] == want["tiering"][key], (
                    variant, want["window"], key
                )


# ---------------------------------------------------------------------------
# 2. Lane equivalence on re-simulated tiering grids (fluid tolerances).
# ---------------------------------------------------------------------------


def _worst_bandwidth_err(ts, tb, cols) -> float:
    worst = 0.0
    for rs, rb in zip(ts.rows, tb.rows):
        for col in cols:
            if rs[col]:
                worst = max(worst, abs(rb[col] - rs[col]) / abs(rs[col]))
    return worst


def test_migrate_interference_lane_equivalence():
    """Fluid vs DES on the migration-interference race, zero fallbacks.

    Tolerances were measured on the scalar baselines and pinned with ~2x
    margin.  The app's own traffic tracks closely (demand_only ≤0.5%,
    naive/miku ddr within 5.2%); the loose column is the *migration
    victim's* small cxl flow (15.8 vs 13.8 GB/s under miku, 12.6%) —
    the fluid λ-collapse slightly over-starves the flow the scalar DES
    starves through per-event FIFO arbitration.  What the grid is *for*
    — the naive-degrades / MIKU-recovers contrast — must survive the
    lane change exactly."""
    ts = run_scenario("migrate_interference", {})
    tb = run_scenario("migrate_interference", {}, lane="batched")
    assert tb.meta["scalar_fallback_jobs"] == 0
    assert tb.meta["fallback_reason_counts"] == {}
    errs = {
        (rs["variant"], col): abs(rb[col] - rs[col]) / abs(rs[col])
        for rs, rb in zip(ts.rows, tb.rows)
        for col in ("ddr_gbps", "cxl_gbps", "mig_gbps")
        if rs[col]
    }
    # Uncontended cells are near-exact; the app's DDR lane is tight
    # everywhere; only the starved victim's cxl flow runs loose.
    for (variant, col), err in errs.items():
        if variant == "demand_only":
            assert err <= 0.02, (variant, col, err)
        elif col == "ddr_gbps":
            assert err <= 0.10, (variant, col, err)
        else:
            assert err <= 0.25, (variant, col, err)
    # The headline result survives the lane change: naive migration
    # degrades DDR, MIKU coordination recovers it.
    rows = {r["variant"]: r for r in tb.rows}
    assert rows["naive"]["ddr_pct_of_demand_only"] < 90.0
    assert rows["miku"]["ddr_pct_of_demand_only"] > 97.0
    assert rows["miku"]["deferred_jobs"] > 0


def test_tiering_policies_lane_equivalence():
    """Fluid vs DES on the hotness-tiering grid, zero fallbacks.

    Static-placement rows are near-exact (measured 0.05%) — with tiering
    quiescent the fluid equilibrium and the DES agree to numerical noise,
    so they are pinned tight.  The hotness_lru rows mix routes mid-flight
    (the app splits fast/slow while the migration engine loads the slow
    tier), and there the fluid per-core-fair station allocation under
    λ-collapse under-serves the mixed-route app (measured 45% low on
    bandwidth).  That row is pinned at its measured error — it documents
    a known fluid-model regime, not an acceptance bar — while the
    *tiering mechanics* (placement convergence, migration activity) are
    asserted to agree across lanes."""
    ts = run_scenario("tiering_policies", {})
    tb = run_scenario("tiering_policies", {}, lane="batched")
    assert tb.meta["scalar_fallback_jobs"] == 0
    for rs, rb in zip(ts.rows, tb.rows):
        assert rb["policy"] == rs["policy"]
        err = abs(rb["app_gbps"] - rs["app_gbps"]) / abs(rs["app_gbps"])
        if rs["policy"] == "static":
            assert err <= 0.02, (rs["platform"], err)
            assert rb["pages_promoted"] == rs["pages_promoted"] == 0
        else:
            assert err <= 0.55, (rs["platform"], err)
            # Both lanes converge the hot set onto the fast tier...
            assert abs(rb["app_fast_fraction"] - rs["app_fast_fraction"]) \
                <= 0.15, (rs["platform"],)
            assert rb["app_fast_fraction"] > 0.6
            # ...through comparable migration traffic (rates differ with
            # the equilibrium, so counts match to a factor, not exactly).
            assert rs["pages_promoted"] > 200 and rb["pages_promoted"] > 200
            assert rb["pages_promoted"] <= 2 * rs["pages_promoted"]
            assert rb["pages_demoted"] <= 2 * rs["pages_demoted"]


# ---------------------------------------------------------------------------
# 3. Telemetry: batched window records + cross-lane trace schema.
# ---------------------------------------------------------------------------


def test_batched_window_records_carry_migration_counters():
    # The CI gating smoke in test form: one-cell batched tiering grid, the
    # per-window records must carry the tiering block's migration counters.
    table = run_scenario(
        "migrate_interference", {"sim_ns": 60_000.0},
        trace=True, lane="batched",
    )
    assert table.meta["scalar_fallback_jobs"] == 0
    tiering_jobs = [
        j for t in table.traces for j in t["jobs"]
        if any("tiering" in rec for rec in j["windows"])
    ]
    assert tiering_jobs, "no batched job recorded a tiering block"
    for j in tiering_jobs:
        for rec in j["windows"]:
            assert set(_GOLDEN_KEYS) <= set(rec["tiering"])
    # At least one window actually retired pages on the batched lane.
    assert any(
        rec["tiering"]["promoted"] or rec["tiering"]["migrated_bytes"]
        for j in tiering_jobs for rec in j["windows"]
    )


def test_trace_payload_schema_matches_across_lanes():
    overrides = {"sim_ns": 60_000.0}
    ts = run_scenario("migrate_interference", overrides, trace=True)
    tb = run_scenario("migrate_interference", overrides, trace=True,
                      lane="batched")
    assert len(ts.traces) == len(tb.traces)
    for cs, cb in zip(ts.traces, tb.traces):
        assert cb["cell"] == cs["cell"]
        assert len(cb["jobs"]) == len(cs["jobs"])
        for js, jb in zip(cs["jobs"], cb["jobs"]):
            assert jb["workloads"] == js["workloads"]
            assert len(jb["windows"]) == len(js["windows"])
            for rs, rb in zip(js["windows"], jb["windows"]):
                assert set(rb) == set(rs)  # window/t_ns/tiers/decision/...
                assert rb["window"] == rs["window"]
                if "tiers" in rs:
                    assert set(rb["tiers"]) == set(rs["tiers"])
                    for tier, tc in rs["tiers"].items():
                        assert set(rb["tiers"][tier]) == set(tc)
                        assert (set(rb["tiers"][tier]["class_counts"])
                                == set(tc["class_counts"]))
                if "decision" in rs:
                    assert set(rb["decision"]) == set(rs["decision"])
                    for tier, d in rs["decision"].items():
                        assert set(rb["decision"][tier]) == set(d)
                if "tiering" in rs:
                    assert set(rb["tiering"]) == set(rs["tiering"])
    # The jsonable contract --trace relies on: both payloads serialize.
    json.dumps(ts.traces)
    json.dumps(tb.traces)

"""Per-tier control-plane contract tests (vector counters, per-tier laws,
tier-addressed apply).

Four contracts:

1. **Deprecation pins** — the legacy ``(fast, slow)`` wrappers
   (``MikuController.window(fast, slow)`` and
   ``TierSetWindowedCounters(merged=True)``) stay signature-compatible and
   emit exactly one DeprecationWarning per process.
2. **Vector bit-identity** — replaying the recorded two-tier seed trace as
   per-tier TierWindows through the vector path reproduces the seed's
   decision sequence verbatim (the vector degenerates to the pair).
3. **Golden per-tier traces** — ``corun3_switch``'s co-run under the
   per-tier ensemble and under the explicit MergedSlowPolicy reproduces the
   recorded decision sequences (``tests/data/pertier_trace_*.json``), both
   replayed law-only and re-simulated end to end.
4. **Merging algebra** — folding per-tier window deltas is associative and
   equals the legacy merged delta (hypothesis property).
"""

import json
import os
import warnings

import pytest

from repro.core.controller import (
    Decision,
    MikuController,
    Phase,
    TierDecisions,
)
from repro.core.des import TieredMemorySim
from repro.core.device_model import platform_a, platform_a_switch
from repro.core.littles_law import (
    OpClass,
    TierCounters,
    TierWindow,
    merge_tier_counters,
)
from repro.core.substrate import (
    ControlLoop,
    ReplaySubstrate,
    TierSetWindowedCounters,
)
from repro.memsim.calibration import default_miku, merged_miku
from repro.memsim.workloads import bw_test

DATA = os.path.join(os.path.dirname(__file__), "data")
P = platform_a()
P3 = platform_a_switch()


def _counters(d) -> TierCounters:
    return TierCounters(
        inserts=d["inserts"],
        occupancy_time=d["occupancy_time"],
        class_counts={OpClass(k): v for k, v in d["class_counts"].items()},
    )


def _pair_win(n_fast, t_fast, n_slow, t_slow, op=OpClass.LOAD):
    f, s = TierCounters(), TierCounters()
    for _ in range(n_fast):
        f.record(op, t_fast)
    for _ in range(n_slow):
        s.record(op, t_slow)
    return f, s


# -- deprecation pins ---------------------------------------------------------


def test_two_arg_window_deprecated_once_and_signature_compatible():
    ctl = default_miku(P)
    MikuController._warned_pair = False
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        d = ctl.window(*_pair_win(50, 100.0, 50, 5000.0))
    assert [w for w in caught if issubclass(w.category, DeprecationWarning)]
    # legacy return type and fields, exactly as the seed controller
    assert isinstance(d, Decision) and not isinstance(d, TierDecisions)
    assert d.phase is Phase.RESTRICTED and d.max_concurrency == 1
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        ctl.window(*_pair_win(50, 100.0, 50, 5000.0))
    assert not [w for w in caught
                if issubclass(w.category, DeprecationWarning)]  # fired once


def test_two_arg_window_equals_vector_single_slow_tier():
    """The deprecated pair form and a two-tier vector make identical
    decisions (the vector degenerates to today's pair)."""
    pair_ctl, vec_ctl = default_miku(P), default_miku(P)
    MikuController._warned_pair = True  # silence; already pinned above
    series = [
        _pair_win(50, 100.0, 50, 5000.0),
        _pair_win(50, 100.0, 50, 6000.0),
        _pair_win(50, 100.0, 50, 300.0),
        _pair_win(50, 100.0, 50, 250.0),
    ]
    for f, s in series:
        dp = pair_ctl.window(f, s)
        dv = vec_ctl.window(TierWindow((f, s), ("ddr", "cxl")))
        assert isinstance(dv, TierDecisions) and dv.tiers == ("cxl",)
        assert (dv.max_concurrency, dv.rate_factor, dv.phase) == (
            dp.max_concurrency, dp.rate_factor, dp.phase)
        assert dv.for_tier("cxl").max_concurrency == dp.max_concurrency


def test_merged_mode_counters_deprecated_and_equal_to_vector_fold():
    TierSetWindowedCounters._warned_merged = False
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        legacy = TierSetWindowedCounters(3, merged=True)
    assert [w for w in caught if issubclass(w.category, DeprecationWarning)]
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        TierSetWindowedCounters(3, merged=True)
    assert not [w for w in caught
                if issubclass(w.category, DeprecationWarning)]  # once

    vector = TierSetWindowedCounters(names=("ddr", "cxl", "cxl_sw"))
    for tc_set in (legacy, vector):
        tc_set.tiers[0].record(OpClass.LOAD, 10.0)
        tc_set.tiers[1].record(OpClass.STORE, 50.0)
        tc_set.tiers[2].record(OpClass.LOAD, 70.0)
        tc_set.tiers[2].record(OpClass.NT_STORE, 90.0)
    fast_l, slow_l = legacy.delta()
    win = vector.delta()
    assert isinstance(win, TierWindow) and win.names == ("ddr", "cxl", "cxl_sw")
    assert fast_l == win.fast
    assert slow_l == win.merged_slow()
    # consume-on-read in both modes
    assert legacy.delta()[1].inserts == 0
    assert vector.delta().merged_slow().inserts == 0


# -- merging algebra ----------------------------------------------------------


def _win(*counters, names=None):
    names = names or tuple(f"t{i}" for i in range(len(counters)))
    return TierWindow(tuple(counters), names)


def test_tier_window_merge_identities():
    """Empty-window merge is the identity; a single-tier window merges
    element-wise; mismatched tier names are rejected loudly."""
    a, b = TierCounters(), TierCounters()
    a.record(OpClass.LOAD, 10.0)
    a.record(OpClass.STORE, 20.0)
    b.record(OpClass.NT_STORE, 5.0)

    # empty-window identity (both orders)
    win = _win(a, b, names=("ddr", "cxl"))
    zero = TierWindow.zero(("ddr", "cxl"))
    for merged in (win.merge(zero), zero.merge(win)):
        assert merged.names == ("ddr", "cxl")
        assert list(merged) == [a, b]

    # single-tier identity: fold of one window with itself doubles counts
    single = _win(a, names=("ddr",))
    doubled = single.merge(single)
    assert doubled[0].inserts == 2 * a.inserts
    assert doubled[0].occupancy_time == pytest.approx(2 * a.occupancy_time)

    # name mismatch (and therefore arity mismatch) is a loud error
    with pytest.raises(ValueError, match="different tier sets"):
        win.merge(_win(a, b, names=("ddr", "cxl_sw")))
    with pytest.raises(ValueError, match="different tier sets"):
        win.merge(_win(a, names=("ddr",)))

    # merge_tier_counters identities: empty fold and singleton fold
    assert merge_tier_counters([]) == TierCounters()
    assert merge_tier_counters([a]) == a


def test_merge_is_associative_and_matches_legacy_merged_delta():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @st.composite
    def tier_counters(draw):
        tc = TierCounters()
        for op in OpClass:
            n = draw(st.integers(0, 20))
            for _ in range(n):
                tc.record(op, draw(st.floats(0.0, 1e4)))
        return tc

    @given(a=tier_counters(), b=tier_counters(), c=tier_counters())
    @settings(max_examples=50, deadline=None)
    def prop(a, b, c):
        left = merge_tier_counters([merge_tier_counters([a, b]), c])
        right = merge_tier_counters([a, merge_tier_counters([b, c])])
        assert left.inserts == right.inserts
        assert left.occupancy_time == pytest.approx(right.occupancy_time)
        assert left.class_counts == right.class_counts
        # ... and equals the legacy merged-slow window over the same vector
        win = TierWindow((TierCounters(), a, b, c))
        folded = win.merged_slow()
        assert folded.inserts == a.inserts + b.inserts + c.inserts
        assert folded.occupancy_time == pytest.approx(
            a.occupancy_time + b.occupancy_time + c.occupancy_time)
        # TierWindow.merge: zero is the identity, and the element-wise fold
        # commutes with merged_slow()
        zero = TierWindow.zero(win.names)
        assert list(win.merge(zero)) == list(win)
        wa = TierWindow((a, b), ("f", "s"))
        wb = TierWindow((b, c), ("f", "s"))
        both = wa.merge(wb)
        assert both.merged_slow().inserts == b.inserts + c.inserts
        assert both.fast == merge_tier_counters([a, b])

    prop()


# -- vector bit-identity with the recorded two-tier seed trace ----------------


def _load_pair_trace(name):
    with open(os.path.join(DATA, name)) as f:
        windows = json.load(f)["windows"]
    deltas = [
        TierWindow((_counters(w["fast"]), _counters(w["slow"])),
                   ("ddr", "cxl"))
        for w in windows
    ]
    return deltas, [w["decision"] for w in windows]


def test_vector_replay_reproduces_seed_two_tier_decisions():
    """The seed's recorded (fast, slow) trace, replayed as two-tier
    TierWindows through the vector path, yields the identical decision
    sequence — the existing pin extended to the vector contract."""
    deltas, golden = _load_pair_trace("miku_trace_des.json")
    sub = ReplaySubstrate(deltas)
    loop = ControlLoop(sub, default_miku(P), window_ns=1.0)
    while not sub.exhausted:
        loop.fire()
    assert len(loop.decisions) == len(golden)
    for d, g in zip(loop.decisions, golden):
        assert isinstance(d, TierDecisions) and d.tiers == ("cxl",)
        assert d.max_concurrency == g["max_concurrency"]
        assert d.rate_factor == g["rate_factor"]
        assert d.phase.value == g["phase"]
    assert sub.applied == loop.decisions  # tier-addressed apply, in order


def test_des_counters_delta_speaks_the_vector_contract():
    wls = [bw_test("ddr", OpClass.LOAD, 2, name="a", miku_managed=False)]
    sim = TieredMemorySim(P3, wls, seed=0)
    sim.run(20_000.0)
    win = sim.counters_delta()
    assert isinstance(win, TierWindow)
    assert win.names == ("ddr", "cxl", "cxl_sw")
    assert len(win) == 3 and win.fast.inserts > 0


# -- golden per-tier decision traces (corun3_switch) --------------------------


def _load_pertier_trace(law):
    with open(os.path.join(DATA, f"pertier_trace_{law}.json")) as f:
        blob = json.load(f)
    names = tuple(blob["tier_names"])
    deltas, golden = [], []
    for w in blob["windows"]:
        deltas.append(TierWindow(
            tuple(_counters(w["tiers"][t]) for t in names), names))
        golden.append(w["decision"])
    return blob, names, deltas, golden


def _law_controller(law, platform):
    return default_miku(platform) if law == "pertier" else merged_miku(platform)


def _assert_tier_decisions_match(decisions, golden, slow_names):
    assert len(decisions) == len(golden)
    for i, (d, g) in enumerate(zip(decisions, golden)):
        assert isinstance(d, TierDecisions) and d.tiers == slow_names, i
        for t in slow_names:
            dt, gt = d.for_tier(t), g[t]
            assert dt.max_concurrency == gt["max_concurrency"], (i, t)
            assert dt.rate_factor == gt["rate_factor"], (i, t)
            assert dt.phase.value == gt["phase"], (i, t)


@pytest.mark.parametrize("law", ["pertier", "merged"])
def test_replayed_pertier_trace_reproduces_golden_decisions(law):
    blob, names, deltas, golden = _load_pertier_trace(law)
    sub = ReplaySubstrate(deltas)
    loop = ControlLoop(sub, _law_controller(law, P3), window_ns=1.0)
    while not sub.exhausted:
        loop.fire()
    _assert_tier_decisions_match(loop.decisions, golden, names[1:])


@pytest.mark.parametrize("law", ["pertier", "merged"])
def test_live_corun3_reproduces_golden_decisions(law):
    """End to end: the 3-tier co-run re-simulated under each law emits the
    recorded decision sequence (and therefore identical throttling)."""
    blob, names, _, golden = _load_pertier_trace(law)
    op = OpClass(blob["op"])
    wls = [bw_test("ddr", op, blob["n_threads"], name="ddr",
                   miku_managed=False),
           bw_test("cxl", op, blob["n_threads"], name="cxl"),
           bw_test("cxl_sw", op, blob["n_threads"], name="cxl_sw")]
    sim = TieredMemorySim(P3, wls, seed=0,
                          controller=_law_controller(law, P3),
                          window_ns=blob["window_ns"])
    res = sim.run(blob["sim_ns"])
    _assert_tier_decisions_match(res.decisions, golden, names[1:])


def test_pertier_ladders_differ_where_merged_cannot():
    """The per-tier golden throttles the switch tier harder than local CXL;
    the merged golden is structurally incapable of that (broadcast)."""
    _, _, _, per = _load_pertier_trace("pertier")
    _, _, _, mer = _load_pertier_trace("merged")
    for g in mer:
        assert g["cxl"]["max_concurrency"] == g["cxl_sw"]["max_concurrency"]
        assert g["cxl"]["rate_factor"] == g["cxl_sw"]["rate_factor"]

    def mean_cap(gs, tier, top=16.0):
        caps = [g[tier]["max_concurrency"] for g in gs]
        return sum(top if c is None else c for c in caps) / len(caps)

    assert mean_cap(per, "cxl_sw") < mean_cap(per, "cxl")


# -- tier-addressed apply -----------------------------------------------------


def test_des_apply_addresses_tiers_independently():
    wls = [bw_test("cxl", OpClass.LOAD, 4, name="b"),
           bw_test("cxl_sw", OpClass.LOAD, 4, name="c")]
    sim = TieredMemorySim(P3, wls, seed=0)
    restricted = Decision(max_concurrency=1, rate_factor=0.5,
                          phase=Phase.RESTRICTED)
    open_d = Decision(max_concurrency=None, rate_factor=1.0,
                      phase=Phase.UNRESTRICTED)
    sim.apply(TierDecisions(tiers=("cxl", "cxl_sw"),
                            decisions=(restricted, open_d)))
    assert sim._limit[0] == 1 and not sim._unthrottled[0]  # cxl workload
    assert sim._limit[1] is None and sim._unthrottled[1]  # cxl_sw workload
    # broadcast legacy decision still reaches every slow tier
    sim.apply(restricted)
    assert sim._limit[0] == 1 and sim._limit[1] == 1
    # wrong arity is a loud error
    with pytest.raises(ValueError, match="slow tier"):
        sim.apply(TierDecisions(tiers=("cxl",), decisions=(restricted,)))


def test_striped_workload_obeys_most_restrictive_touched_tier():
    import dataclasses

    wl = dataclasses.replace(
        bw_test("ddr", OpClass.LOAD, 4, name="s"),
        placement={"ddr": 0.4, "cxl": 0.3, "cxl_sw": 0.3},
    )
    sim = TieredMemorySim(P3, [wl], seed=0)
    sim.apply(TierDecisions(
        tiers=("cxl", "cxl_sw"),
        decisions=(Decision(max_concurrency=4, rate_factor=1.0,
                            phase=Phase.RESTRICTED),
                   Decision(max_concurrency=2, rate_factor=0.25,
                            phase=Phase.RESTRICTED)),
    ))
    assert sim._limit[0] == 2  # min across touched slow tiers
    assert sim._rate[0] == 0.25


def test_transfer_queue_per_tier_links_and_decisions():
    from repro.core.offload import TransferQueue
    from repro.core.tiers import TierSpec

    far = TierSpec(name="far_host", memory_kind="pinned_host",
                   bandwidth_gbps=8.0, capacity_gib=512.0, parallelism=4)
    q = TransferQueue(extra_slow=(far,))
    assert list(q.slow_tiers) == ["slow", "far_host"]
    q.apply(TierDecisions(
        tiers=("slow", "far_host"),
        decisions=(Decision(max_concurrency=None, rate_factor=1.0,
                            phase=Phase.UNRESTRICTED),
                   Decision(max_concurrency=2, rate_factor=1.0,
                            phase=Phase.RESTRICTED)),
    ))
    q.submit_slow_stream(1 << 20, 32, tier="slow")
    q.submit_slow_stream(1 << 20, 32, tier="far_host")
    # the uncapped link floods descriptors; the capped link holds <= 2
    assert q.slow_inflight("slow") == 32
    assert q.slow_inflight("far_host") == 2
    assert q.slow_backlog("slow") > 0
    # per-tier counters exist and fill as transfers retire
    q.advance(5e8)
    assert q.counters["slow"].inserts == 32
    assert q.counters["far_host"].inserts == 32
    win = q.counters_delta()
    assert isinstance(win, TierWindow)
    assert win.names == ("fast", "slow", "far_host")


# -- scenario + trace plumbing ------------------------------------------------


def test_corun3_pertier_scenario_acceptance():
    """CLI-runnable demonstrator: per-tier ladders throttle the switch tier
    harder than local CXL while DDR recovers to near-peak; the merged law
    cannot tell the tiers apart."""
    from repro.scenarios import run_scenario

    table = run_scenario(
        "corun3_pertier",
        {"law": ("merged", "pertier"), "sim_ns": 200_000.0},
        trace=True,
    )
    rows = {r["law"]: r for r in table.rows}
    per, mer = rows["pertier"], rows["merged"]
    assert per["ddr_pct_of_opt"] > 90.0  # near-peak DDR recovery
    assert per["cxl_sw_mean_cap"] < per["cxl_mean_cap"]  # switch hit harder
    assert per["cxl_sw_restricted_windows"] > 0
    assert mer["cxl_mean_cap"] == mer["cxl_sw_mean_cap"]  # merged: can't
    # per-tier telemetry was traced for every cell
    assert table.traces is not None and len(table.traces) == 2
    windows = table.traces[1]["jobs"][3]["windows"]
    assert windows, "co-run job must carry per-window telemetry"
    assert set(windows[0]["tiers"]) == {"ddr", "cxl", "cxl_sw"}
    assert set(windows[0]["decision"]) == {"cxl", "cxl_sw"}


def test_trace_rejected_for_multistage_scenarios():
    from repro.scenarios import run_scenario

    with pytest.raises(ValueError, match="multi-stage"):
        run_scenario("fig2_tiering", {"op": (OpClass.LOAD,)}, trace=True)

"""Control-plane substrate tests.

The refactor contract: a ControlLoop driving a fake (replay) substrate must
produce byte-identical Decision sequences to the pre-refactor DES and
TransferQueue window plumbing.  ``tests/data/miku_trace_*.json`` are counter
traces (per-window fast/slow deltas + the decision the seed code emitted)
recorded from the seed implementation before the refactor.
"""

import json
import os

import pytest

from repro.core.controller import StragglerGovernor
from repro.core.des import run_bw_test, run_corun
from repro.core.device_model import platform_a
from repro.core.littles_law import OpClass, TierCounters
from repro.core.substrate import (
    ControlLoop,
    ReplaySubstrate,
    StepTimingSubstrate,
    WindowedCounters,
)
from repro.memsim.calibration import default_miku

DATA = os.path.join(os.path.dirname(__file__), "data")
P = platform_a()


def _counters(d) -> TierCounters:
    return TierCounters(
        inserts=d["inserts"],
        occupancy_time=d["occupancy_time"],
        class_counts={OpClass(k): v for k, v in d["class_counts"].items()},
    )


def _load_trace(name):
    with open(os.path.join(DATA, name)) as f:
        windows = json.load(f)["windows"]
    deltas = [(_counters(w["fast"]), _counters(w["slow"])) for w in windows]
    golden = [w["decision"] for w in windows]
    return deltas, golden


def _assert_decisions_match(decisions, golden):
    assert len(decisions) == len(golden)
    for i, (d, g) in enumerate(zip(decisions, golden)):
        assert d.max_concurrency == g["max_concurrency"], i
        assert d.rate_factor == g["rate_factor"], i
        assert d.phase.value == g["phase"], i


@pytest.mark.parametrize("trace", ["miku_trace_des.json", "miku_trace_tq.json"])
def test_replayed_trace_reproduces_seed_decisions(trace):
    """ControlLoop + fake substrate == the seed's bespoke window plumbing."""
    deltas, golden = _load_trace(trace)
    sub = ReplaySubstrate(deltas)
    loop = ControlLoop(sub, default_miku(P), window_ns=1.0)
    while not sub.exhausted:
        loop.fire()
    _assert_decisions_match(loop.decisions, golden)
    # apply() received every decision, in order.
    assert sub.applied == loop.decisions


def test_live_des_reproduces_recorded_decision_sequence():
    """The ported DES end-to-end: same config as the recorded seed run →
    identical decision sequence (and therefore identical throttling)."""
    _, golden = _load_trace("miku_trace_des.json")
    res = run_corun(
        P, op=OpClass.STORE, n_threads=16, sim_ns=400_000,
        controller=default_miku(P),
    )
    _assert_decisions_match(res.decisions, golden)


def test_windowed_counters_consume_on_read():
    wc = WindowedCounters()
    wc.fast.record(OpClass.LOAD, 10.0)
    wc.slow.record(OpClass.STORE, 50.0)
    f, s = wc.delta()
    assert f.inserts == 1 and s.inserts == 1
    assert s.class_counts[OpClass.STORE] == 1
    f, s = wc.delta()  # consumed: second read is empty
    assert f.inserts == 0 and s.inserts == 0
    wc.fast.record(OpClass.LOAD, 5.0)
    f, _ = wc.delta()
    assert f.inserts == 1 and f.occupancy_time == 5.0


def test_control_loop_poll_catches_up_all_boundaries():
    class Clocked:
        now = 0.0
        clock_ns = property(lambda self: self.now)
        calls = 0

        def counters_delta(self):
            self.calls += 1
            return (TierCounters(), TierCounters())

        def apply(self, decision):
            pass

    class CountingLaw:
        def __init__(self):
            self.n = 0

        def window(self, fast, slow):
            self.n += 1
            return self.n

    sub = Clocked()
    loop = ControlLoop(sub, CountingLaw(), window_ns=10.0)
    sub.now = 35.0
    fired = loop.poll()
    assert fired == [1, 2, 3]
    assert loop.next_window_ns == 40.0
    assert not loop.due()


def test_step_timing_substrate_drives_straggler_governor():
    sub = StepTimingSubstrate(n_hosts=4)
    loop = ControlLoop(sub, StragglerGovernor(n_hosts=4, patience=1),
                       window_ns=1.0)
    for _ in range(3):
        for h, t in enumerate([1.0, 1.0, 1.0, 5.0]):
            sub.record_step(h, t)
        loop.fire()
    assert sub.rate_factor(3) < 1.0
    assert all(sub.rate_factor(h) == 1.0 for h in range(3))
    assert loop.windows_run == 3


def test_fig_goldens_unchanged_quick():
    """Fast-path rewrite must not move the figure numbers (load column)."""
    with open(os.path.join(DATA, "seed_fig_goldens.json")) as f:
        gold = json.load(f)
    for row in gold["fig3"]:
        if row["op"] != "load":
            continue
        r = run_bw_test(P, op=OpClass.LOAD, tier=row["tier"], n_threads=16,
                        sim_ns=120_000)
        bw = r.bandwidth(f"bw-{row['tier']}-load")
        assert bw == pytest.approx(row["bandwidth_gbps"], rel=0.01)
    both = run_corun(P, op=OpClass.LOAD, n_threads=16, sim_ns=300_000)
    g = gold["fig5"]["load"]
    assert both.bandwidth("ddr") == pytest.approx(g["ddr_gbps"], rel=0.01)
    assert both.bandwidth("cxl") == pytest.approx(g["cxl_gbps"], rel=0.01)
    assert both.tor_inserts == g["tor_inserts"]


@pytest.mark.slow
def test_fig_goldens_unchanged_full_matrix():
    with open(os.path.join(DATA, "seed_fig_goldens.json")) as f:
        gold = json.load(f)
    for row in gold["fig3"]:
        op = OpClass(row["op"])
        r = run_bw_test(P, op=op, tier=row["tier"], n_threads=16,
                        sim_ns=120_000)
        bw = r.bandwidth(f"bw-{row['tier']}-{op.value}")
        assert bw == pytest.approx(row["bandwidth_gbps"], rel=0.01)
    for opv, g in gold["fig5"].items():
        both = run_corun(P, op=OpClass(opv), n_threads=16, sim_ns=300_000)
        assert both.bandwidth("ddr") == pytest.approx(g["ddr_gbps"], rel=0.01)
        assert both.bandwidth("cxl") == pytest.approx(g["cxl_gbps"], rel=0.01)
        assert both.tor_inserts == g["tor_inserts"]
        assert both.tor_peak == g["tor_peak"]


def test_reservoir_percentiles_within_tolerance():
    """Bounded reservoir vs full-capture percentiles on the same sim."""
    from repro.core.des import TieredMemorySim, WorkloadSpec

    wl = WorkloadSpec(name="w", op=OpClass.LOAD, tier="cxl", n_cores=16)
    full = TieredMemorySim(P, [wl], seed=3, latency_reservoir=10**9)
    rf = full.run(120_000.0).stats["w"]
    assert rf.latency_count == len(rf.latency_samples)  # captured everything

    bounded = TieredMemorySim(P, [wl], seed=3, latency_reservoir=2048)
    rb = bounded.run(120_000.0).stats["w"]
    assert len(rb.latency_samples) == 2048
    for q in (0.5, 0.9, 0.99):
        assert rb.percentile_ns(q) == pytest.approx(rf.percentile_ns(q),
                                                    rel=0.05)

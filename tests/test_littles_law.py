"""Estimator unit + property tests (paper Eq. 1)."""

import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.littles_law import (
    EstimatorConfig,
    LittlesLawEstimator,
    OpClass,
    TierCounters,
)


def window(n_fast, t_fast, n_slow, t_slow, op=OpClass.LOAD):
    f = TierCounters()
    s = TierCounters()
    for _ in range(n_fast):
        f.record(op, t_fast)
    for _ in range(n_slow):
        s.record(op, t_slow)
    return f, s


def test_eq1_exact_recovery():
    cfg = EstimatorConfig(t_fast=100.0, slow_read_threshold=500.0, ewma=1.0)
    est = LittlesLawEstimator(cfg)
    f, s = window(50, 100.0, 50, 900.0)
    out = est.update(f, s)
    assert out.valid
    assert out.t_slow_raw == pytest.approx(900.0, rel=1e-6)
    assert out.backlogged


@given(
    n_fast=st.integers(8, 500),
    n_slow=st.integers(4, 500),
    t_slow=st.floats(1.0, 1e5),
)
@settings(max_examples=100, deadline=None)
def test_eq1_property(n_fast, n_slow, t_slow):
    """With exact t_fast calibration, Eq.1 recovers t_slow exactly for any
    mix (conditioning guard permitting)."""
    t_fast = 100.0
    cfg = EstimatorConfig(t_fast=t_fast, slow_read_threshold=1e9, ewma=1.0,
                          min_window_inserts=4, min_slow_inserts=1)
    est = LittlesLawEstimator(cfg)
    f, s = window(n_fast, t_fast, n_slow, t_slow)
    out = est.update(f, s)
    alpha = n_fast / (n_fast + n_slow)
    if alpha <= cfg.alpha_calm:
        assert out.t_slow_raw == pytest.approx(t_slow, rel=1e-3)
    else:  # ill-conditioned corner: direct measurement fallback
        assert out.t_slow_raw == pytest.approx(t_slow, rel=1e-3)


def test_threshold_mix_calibration():
    """Paper footnote 2: nt-store threshold = 2x read, store = 1.5x."""
    cfg = EstimatorConfig(t_fast=100.0, slow_read_threshold=1000.0)
    est = LittlesLawEstimator(cfg)
    loads = TierCounters()
    loads.record(OpClass.LOAD, 1.0)
    assert est.threshold_for_mix(loads) == pytest.approx(1000.0)
    nt = TierCounters()
    nt.record(OpClass.NT_STORE, 1.0)
    assert est.threshold_for_mix(nt) == pytest.approx(2000.0)
    stores = TierCounters()
    stores.record(OpClass.STORE, 1.0)
    assert est.threshold_for_mix(stores) == pytest.approx(1500.0)


def test_invalid_window_below_min_inserts():
    cfg = EstimatorConfig(t_fast=100.0, slow_read_threshold=500.0)
    est = LittlesLawEstimator(cfg)
    f, s = window(1, 100.0, 1, 1e9)
    out = est.update(f, s)
    assert not out.valid and not out.backlogged


def test_counters_delta_and_merge():
    a = TierCounters()
    a.record(OpClass.LOAD, 10.0)
    snap = a.snapshot()
    a.record(OpClass.STORE, 20.0)
    d = a.delta(snap)
    assert d.inserts == 1 and d.occupancy_time == 20.0
    b = TierCounters()
    b.merge(a)
    assert b.inserts == a.inserts


@given(st.lists(st.floats(1.0, 1e4), min_size=1, max_size=30))
@settings(max_examples=50, deadline=None)
def test_mean_service_time_is_mean(residencies):
    c = TierCounters()
    for r in residencies:
        c.record(OpClass.LOAD, r)
    assert c.mean_service_time == pytest.approx(
        sum(residencies) / len(residencies), rel=1e-9
    )

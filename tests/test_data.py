"""Data pipeline: determinism, packing invariants."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import HostDataLoader, SyntheticTokenDataset, pack_documents


def test_loader_deterministic_across_instances():
    ds = SyntheticTokenDataset(vocab=512)
    a = HostDataLoader(ds, global_batch=4, seq_len=64)
    b = HostDataLoader(ds, global_batch=4, seq_len=64)
    ta, la = next(a)
    tb, lb = next(b)
    np.testing.assert_array_equal(ta, tb)
    np.testing.assert_array_equal(la, lb)


def test_loader_resume_continues_stream():
    ds = SyntheticTokenDataset(vocab=512)
    a = HostDataLoader(ds, global_batch=2, seq_len=32)
    next(a)
    state = a.state_dict()
    t2, _ = next(a)
    b = HostDataLoader(ds, global_batch=2, seq_len=32)
    b.load_state_dict(state)
    t2b, _ = next(b)
    np.testing.assert_array_equal(t2, t2b)


def test_shards_are_disjoint():
    ds = SyntheticTokenDataset(vocab=512)
    a = HostDataLoader(ds, global_batch=8, seq_len=32, shard_index=0,
                       num_shards=2)
    b = HostDataLoader(ds, global_batch=8, seq_len=32, shard_index=1,
                       num_shards=2)
    ta, _ = next(a)
    tb, _ = next(b)
    assert ta.shape == tb.shape == (4, 32)
    assert not np.array_equal(ta, tb)


@given(seq_len=st.integers(8, 128), batch=st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_packing_shapes_and_label_shift(seq_len, batch):
    ds = SyntheticTokenDataset(vocab=512, mean_doc_len=20)
    tokens, labels = pack_documents(ds.documents(shard=0), seq_len, batch)
    assert tokens.shape == (batch, seq_len)
    assert labels.shape == (batch, seq_len)
    # labels are tokens shifted by one within the packed row
    np.testing.assert_array_equal(tokens[:, 1:], labels[:, :-1])
    assert tokens.max() < 512 and tokens.min() >= 0

"""Serving engine + tiered cluster behaviour."""

import jax
import pytest

from repro.configs import get_arch
from repro.core.controller import MikuConfig, MikuController
from repro.core.littles_law import EstimatorConfig
from repro.models.transformer import TransformerLM
from repro.serving.engine import (
    EngineConfig,
    Request,
    ServingEngine,
    TieredServingCluster,
)

CFG = get_arch("llama31-8b").smoke
MODEL = TransformerLM(CFG)
PARAMS, _ = MODEL.init(jax.random.PRNGKey(0))


def mk(name, placement, n_req, max_new=8):
    e = ServingEngine(
        EngineConfig(name=name, model=CFG, max_slots=2, max_len=64,
                     placement=placement, stream_chunks=64),
        PARAMS,
    )
    for i in range(n_req):
        e.submit(Request(rid=i, prompt=[1, 2, 3, 4], max_new_tokens=max_new))
    return e


def test_engine_completes_all_requests():
    cl = TieredServingCluster([mk("a", "device", 5)])
    res = cl.run(2000)
    assert res["a"]["requests"] == 5
    assert res["a"]["tokens"] == 5 * 8


def test_continuous_batching_more_requests_than_slots():
    eng = mk("a", "device", 7)
    cl = TieredServingCluster([eng])
    cl.run(4000)
    assert len(eng.done) == 7
    assert all(len(r.output) == 8 for r in eng.done)


def test_host_instance_slower_than_device():
    a = TieredServingCluster([mk("d", "device", 4)]).run(4000)
    b = TieredServingCluster([mk("h", "host", 4)]).run(8000)
    assert a["d"]["tokens_per_s"] > 3 * b["h"]["tokens_per_s"]


@pytest.mark.slow
def test_racing_degrades_fast_instance():
    """Full-length Fig. 12 analogue (slow lane; the quick MIKU-restriction
    check below covers the control path in tier-1)."""
    solo = TieredServingCluster([mk("d", "device", 8)]).run(8000)
    both = TieredServingCluster(
        [mk("d", "device", 8), mk("h", "host", 4)]
    ).run(16000)
    assert both["d"]["tokens_per_s"] < 0.92 * solo["d"]["tokens_per_s"]


def test_miku_restricts_under_racing():
    probe = mk("p", "host", 0)
    chunk_service = probe.param_bytes / 64 / 16.0
    ctl = MikuController(
        MikuConfig(levels=(1, 2, 4, 8)),
        EstimatorConfig(t_fast=1.2e3, slow_read_threshold=8 * chunk_service,
                        min_window_inserts=4, min_slow_inserts=1),
    )
    cl = TieredServingCluster(
        [mk("d", "device", 12), mk("h", "host", 6)],
        controller=ctl, window_ns=3e4,
    )
    cl.run(20000)
    assert any(d.restricted for d in ctl.decisions)

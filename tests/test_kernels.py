"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode executes the exact TPU kernel body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.ops import decode_attention, ssd_scan
from repro.kernels.ref import decode_attention_ref, ssd_scan_ref


def _attn_ref(q, k, v, lengths, **kw):
    b, hq, dh = q.shape
    hkv = k.shape[2]
    return decode_attention_ref(
        q.reshape(b, hkv, hq // hkv, dh),
        jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2), lengths, **kw
    ).reshape(b, hq, dh)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hkv,dh,s,block",
    [
        (1, 4, 4, 64, 128, 64),   # MHA
        (2, 8, 2, 64, 256, 64),   # GQA 4:1
        (2, 16, 2, 128, 512, 128),  # qwen-like 8:1
        (1, 25, 5, 64, 128, 32),  # hymba: 25 heads, G=5 (padding path)
        (2, 20, 20, 64, 128, 64),  # whisper MHA-20
    ],
)
def test_decode_attention_sweep(dtype, b, hq, hkv, dh, s, block):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (b, hq, dh), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, dh), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, dh), dtype)
    lengths = jax.random.randint(ks[3], (b,), 1, s + 1)
    out = decode_attention(q, k, v, lengths, block_s=block)
    ref = _attn_ref(q, k, v, lengths)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        out.astype(np.float32), ref.astype(np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("window,softcap", [(64, None), (1 << 30, 50.0),
                                            (32, 30.0)])
def test_decode_attention_window_softcap(window, softcap):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    b, hq, hkv, dh, s = 2, 8, 4, 64, 256
    q = jax.random.normal(ks[0], (b, hq, dh))
    k = jax.random.normal(ks[1], (b, s, hkv, dh))
    v = jax.random.normal(ks[2], (b, s, hkv, dh))
    lengths = jnp.array([s, s // 3], jnp.int32)
    out = decode_attention(q, k, v, lengths, window=window, softcap=softcap,
                           block_s=64)
    ref = _attn_ref(q, k, v, lengths, window=window, softcap=softcap)
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)


@given(
    b=st.integers(1, 3),
    hkv=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([1, 2, 5]),
    s_blocks=st.integers(1, 4),
)
@settings(max_examples=20, deadline=None)
def test_decode_attention_property(b, hkv, group, s_blocks):
    dh, block = 32, 32
    s = block * s_blocks
    hq = hkv * group
    ks = jax.random.split(jax.random.PRNGKey(b * 131 + hq), 4)
    q = jax.random.normal(ks[0], (b, hq, dh))
    k = jax.random.normal(ks[1], (b, s, hkv, dh))
    v = jax.random.normal(ks[2], (b, s, hkv, dh))
    lengths = jax.random.randint(ks[3], (b,), 1, s + 1)
    out = decode_attention(q, k, v, lengths, block_s=block)
    ref = _attn_ref(q, k, v, lengths)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,p,n,chunk",
    [
        (1, 64, 2, 32, 16, 16),
        (2, 128, 4, 32, 16, 32),
        (1, 256, 2, 64, 128, 64),  # mamba2-class state
    ],
)
def test_ssd_scan_sweep(b, s, h, p, n, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = (jax.random.normal(ks[0], (b, s, h, p)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    bm = jax.random.normal(ks[2], (b, s, n)) * 0.3
    cm = jax.random.normal(ks[3], (b, s, n)) * 0.3
    a = -jnp.exp(jax.random.normal(ks[4], (h,)) * 0.3)
    y = ssd_scan(x, dt, bm, cm, a, chunk=chunk)
    yref = jnp.moveaxis(
        ssd_scan_ref(jnp.moveaxis(x, 2, 1).astype(jnp.float32),
                     jnp.moveaxis(dt, 2, 1),
                     jnp.stack([bm, cm], 2), a), 1, 2)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        y.astype(np.float32), yref.astype(np.float32), atol=tol, rtol=tol
    )


def test_ssd_scan_state_carries_across_chunks():
    """Same sequence, different chunk sizes => identical output (the scratch
    state must carry exactly)."""
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    b, s, h, p, n = 1, 128, 2, 32, 16
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    bm = jax.random.normal(ks[2], (b, s, n)) * 0.3
    cm = jax.random.normal(ks[3], (b, s, n)) * 0.3
    a = -jnp.exp(jax.random.normal(ks[4], (h,)) * 0.3)
    y32 = ssd_scan(x, dt, bm, cm, a, chunk=32)
    y128 = ssd_scan(x, dt, bm, cm, a, chunk=128)
    np.testing.assert_allclose(y32, y128, atol=1e-4, rtol=1e-4)

"""DES microbenchmark: fast-path rewrite vs the seed DES, interleaved A/B.

Measures the fig5 co-run config (LOAD, 16+16 threads, 300 us simulated) on
both the current DES and the pinned seed snapshot
(``benchmarks/_seed_des_baseline.py``), alternating reps so container CPU
throttling hits both sides equally, and verifies the Fig. 3/5 bandwidths
against the recorded seed goldens (they are bit-identical by construction;
1% is the gate).  Also runs the sweep-scale lane A/B: the 96-cell
``corun_sweep`` grid on the scalar process pool vs the batched lane
(``repro.memsim.batched``; ≥5x is the acceptance bar, with the cross-lane
bandwidth deviation recorded alongside), and the kilo-cell A/B/C: the
1024-cell ``corun_sweep_1k`` grid on the scalar pool vs the batched lane
under both solver backends (numpy and the fused jit/Pallas window solver,
``REPRO_BATCH_BACKEND=pallas``; the gate bounds control-decision flips
and the decision-aligned p95 bandwidth deviation — see
``_SWEEP1K_MAX_FLIPS``).  Emits ``BENCH_des.json`` at the repo root.

Usage:  PYTHONPATH=src python benchmarks/bench_des.py [--reps N] [--out PATH]
        PYTHONPATH=src python benchmarks/bench_des.py --sweep-1k   # CI slow
        PYTHONPATH=src python benchmarks/bench_des.py --smoke      # CI fast
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
import time

_HERE = os.path.dirname(os.path.abspath(__file__))
_REPO_ROOT = os.path.dirname(_HERE)
if _REPO_ROOT not in sys.path:
    sys.path.insert(0, _REPO_ROOT)

from repro.core.des import run_bw_test, run_corun
from repro.core.device_model import platform_a
from repro.core.littles_law import OpClass

from benchmarks import _seed_des_baseline as seed_des

_GOLDENS = os.path.join(_REPO_ROOT, "tests", "data", "seed_fig_goldens.json")


def _time(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def bench_ab(reps: int) -> dict:
    p = platform_a()
    kw = dict(op=OpClass.LOAD, n_threads=16, sim_ns=300_000)
    seed_t, new_t = [], []
    completed = 0
    for _ in range(reps):
        seed_t.append(_time(lambda: seed_des.run_corun(p, **kw)))
        t0 = time.perf_counter()
        res = run_corun(p, **kw)
        new_t.append(time.perf_counter() - t0)
        completed = sum(s.completed for s in res.stats.values())
    return {
        "config": "fig5_corun_load_16t_300us",
        "seed_wall_s": {"best": round(min(seed_t), 4),
                        "median": round(statistics.median(seed_t), 4)},
        "corun_wall_s": {"best": round(min(new_t), 4),
                         "median": round(statistics.median(new_t), 4)},
        "speedup_vs_seed": round(min(seed_t) / min(new_t), 2),
        "speedup_vs_seed_median": round(
            statistics.median(seed_t) / statistics.median(new_t), 2),
        "events_per_s": int(completed / min(new_t)),
        "completed_requests": completed,
    }


def check_goldens() -> dict:
    p = platform_a()
    with open(_GOLDENS) as f:
        gold = json.load(f)
    worst = 0.0
    for row in gold["fig3"]:
        op = OpClass(row["op"])
        r = run_bw_test(p, op=op, tier=row["tier"], n_threads=16,
                        sim_ns=120_000)
        bw = r.bandwidth(f"bw-{row['tier']}-{op.value}")
        worst = max(worst, abs(bw - row["bandwidth_gbps"])
                    / max(row["bandwidth_gbps"], 1e-9))
    for opv, g in gold["fig5"].items():
        both = run_corun(p, op=OpClass(opv), n_threads=16, sim_ns=300_000)
        worst = max(worst, abs(both.bandwidth("ddr") - g["ddr_gbps"])
                    / max(g["ddr_gbps"], 1e-9))
        worst = max(worst, abs(both.bandwidth("cxl") - g["cxl_gbps"])
                    / max(g["cxl_gbps"], 1e-9))
    return {
        "goldens_within_1pct": worst < 0.01,
        "goldens_max_rel_err": worst,
    }


def bench_sweep_lanes() -> dict:
    """Sweep-scale lane A/B: the 96-cell ``corun_sweep`` grid, scalar
    process pool vs the batched lane (``repro.memsim.batched``).

    The batched side runs twice and keeps the warm time (first call pays
    numpy/ladder setup); the scalar side runs once through the pool the
    ``--jobs`` path would use.  Also records the worst per-cell bandwidth
    deviation between the lanes — the speedup is only meaningful while the
    lanes agree."""
    import os as _os

    from repro.memsim.sweep import run_sweep
    from repro.scenarios import plan

    jobs = [j for _, _, js in plan("corun_sweep") for j in js]
    procs = max(2, min(8, _os.cpu_count() or 1))
    t0 = time.perf_counter()
    batched = run_sweep(jobs, lane="batched")
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    run_sweep(jobs, lane="batched")
    t_batched = min(t_cold, time.perf_counter() - t0)
    t0 = time.perf_counter()
    scalar = run_sweep(jobs, processes=procs, lane="scalar")
    t_scalar = time.perf_counter() - t0
    errs = []
    for s, b in zip(scalar, batched):
        for w in ("ddr", "cxl"):
            errs.append(abs(b.bandwidth(w) - s.bandwidth(w))
                        / max(s.bandwidth(w), 1e-9))
    return {
        "sweep_scenario": "corun_sweep",
        "sweep_cells": len(jobs),
        "scalar_pool_procs": procs,
        "scalar_pool_wall_s": round(t_scalar, 3),
        "batched_wall_s": round(t_batched, 3),
        "batched_speedup": round(t_scalar / max(t_batched, 1e-9), 1),
        "batched_speedup_ge_5x": t_scalar / max(t_batched, 1e-9) >= 5.0,
        "lane_worst_rel_err": round(max(errs), 4),
        "lane_mean_rel_err": round(sum(errs) / len(errs), 4),
    }


#: Kilo-grid lane gate.  A dense MLP × thread sweep necessarily contains
#: knife-edge cells where the MIKU restriction threshold sits between the
#: two lanes' bandwidth estimates — the lanes then take *different control
#: decisions* and the bandwidth gap is the (real, large) gap between the
#: restricted and unrestricted operating points, not a fluid-model error.
#: The gate therefore (a) bounds how many cells may flip decisions, and
#: (b) bounds the p95 bandwidth error over the decision-aligned cells.
#: Measured on the seed machine: 4/1024 flips, aligned p95 6.5%.
_SWEEP1K_MAX_FLIPS = 12
_SWEEP1K_P95_BOUND = 0.08


def bench_sweep_1k() -> dict:
    """Kilo-cell lane A/B/C: the 1024-cell ``corun_sweep_1k`` grid on the
    scalar pool, the batched numpy lane, and the batched lane with the
    fused jit/Pallas window solver (``REPRO_BATCH_BACKEND=pallas``).

    Each batched side runs twice and keeps the warm time (the first pallas
    call pays jit tracing).  Gates each backend against the scalar DES on
    decision flips + aligned-cell p95 error (see ``_SWEEP1K_MAX_FLIPS``),
    recording the worst aligned/overall deviations for transparency."""
    import os as _os

    from repro.core.controller import Phase
    from repro.memsim.sweep import run_sweep
    from repro.scenarios import plan

    jobs = [j for _, _, js in plan("corun_sweep_1k") for j in js]
    procs = max(2, min(8, _os.cpu_count() or 1))

    def timed_batched():
        t0 = time.perf_counter()
        res = run_sweep(jobs, lane="batched")
        t_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        run_sweep(jobs, lane="batched")
        return res, min(t_cold, time.perf_counter() - t0)

    numpy_res, t_numpy = timed_batched()
    prev = _os.environ.get("REPRO_BATCH_BACKEND")
    _os.environ["REPRO_BATCH_BACKEND"] = "pallas"
    try:
        pallas_res, t_pallas = timed_batched()
    finally:
        if prev is None:
            _os.environ.pop("REPRO_BATCH_BACKEND", None)
        else:
            _os.environ["REPRO_BATCH_BACKEND"] = prev
    t0 = time.perf_counter()
    scalar = run_sweep(jobs, processes=procs, lane="scalar")
    t_scalar = time.perf_counter() - t0

    def _restricted(res) -> bool:
        return any(d.phase == Phase.RESTRICTED for d in res.decisions)

    def lane_stats(batched):
        errs, flips = [], 0
        for s, b in zip(scalar, batched):
            e = max(
                abs(b.bandwidth(w) - s.bandwidth(w))
                / max(s.bandwidth(w), 1e-9)
                for w in ("ddr", "cxl")
            )
            if _restricted(s) != _restricted(b):
                flips += 1
            else:
                errs.append(e)
        errs.sort()
        p95 = errs[int(0.95 * (len(errs) - 1))] if errs else 0.0
        return {
            "decision_flip_cells": flips,
            "aligned_p95_rel_err": round(p95, 4),
            "aligned_worst_rel_err": round(errs[-1] if errs else 0.0, 4),
            "within_gate": (flips <= _SWEEP1K_MAX_FLIPS
                            and p95 <= _SWEEP1K_P95_BOUND),
        }

    st_np = lane_stats(numpy_res)
    st_pl = lane_stats(pallas_res)
    return {
        "sweep_scenario": "corun_sweep_1k",
        "sweep_cells": len(jobs),
        "scalar_pool_procs": procs,
        "scalar_pool_wall_s": round(t_scalar, 3),
        "batched_wall_s": round(t_numpy, 3),
        "batched_speedup": round(t_scalar / max(t_numpy, 1e-9), 1),
        "batched_speedup_ge_5x": t_scalar / max(t_numpy, 1e-9) >= 5.0,
        "pallas_wall_s": round(t_pallas, 3),
        "pallas_speedup": round(t_scalar / max(t_pallas, 1e-9), 1),
        "numpy_lane": st_np,
        "pallas_lane": st_pl,
        "max_decision_flips": _SWEEP1K_MAX_FLIPS,
        "aligned_p95_bound": _SWEEP1K_P95_BOUND,
        "lanes_within_gate": st_np["within_gate"] and st_pl["within_gate"],
    }


def bench_obs(reps: int = 4) -> dict:
    """Observability overhead A/B: the fig5 co-run config with tracing +
    histograms + profiling off vs on, interleaved reps.

    Two gates: (a) the instrumented run must cost < 10% wall time over the
    plain run; (b) the instrumented run's simulation outcome (bandwidth,
    latency sums, ToR inserts) must be *bit-identical* — the deterministic
    sampler draws no random numbers, so observability must never perturb
    the simulation."""
    import dataclasses

    from repro.memsim.sweep import SimJob, run_job
    from repro.memsim.workloads import bw_test

    p = platform_a()
    wls = [
        bw_test("ddr", OpClass.LOAD, 16, name="ddr", miku_managed=False),
        bw_test("cxl", OpClass.LOAD, 16, name="cxl"),
    ]
    base = SimJob(platform=p, workloads=wls, sim_ns=300_000.0, miku=True)
    obs = dataclasses.replace(
        base, trace=64, latency_hist=True, profile=True
    )
    off_t, on_t = [], []
    r_off = r_on = None
    for _ in range(reps):
        t0 = time.perf_counter()
        r_off = run_job(base)
        off_t.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        r_on = run_job(obs)
        on_t.append(time.perf_counter() - t0)
    identical = all(
        r_off.stats[w].bytes == r_on.stats[w].bytes
        and r_off.stats[w].latency_sum == r_on.stats[w].latency_sum
        and r_off.stats[w].completed == r_on.stats[w].completed
        for w in ("ddr", "cxl")
    ) and r_off.tor_inserts == r_on.tor_inserts
    overhead = (min(on_t) / max(min(off_t), 1e-9) - 1.0) * 100.0
    return {
        "config": "fig5_corun_load_16t_300us",
        "plain_wall_s": round(min(off_t), 4),
        "instrumented_wall_s": round(min(on_t), 4),
        "obs_overhead_pct": round(overhead, 2),
        "obs_within_10pct": overhead < 10.0,
        "obs_bit_identical": identical,
        "traced_requests": r_on.trace["n_traced"],
        "phase_profile": r_on.profile,
    }


def check_fast_path_overhead(out: dict, snapshot_path: str) -> dict:
    """Two-tier fast-path overhead gate for the per-tier contract.

    Compares this run's interleaved A/B speedup-vs-seed against the
    committed BENCH_des.json snapshot's.  The speedup ratio is
    machine-robust (both sides of each A/B pair ran on the same box), so a
    drop > 5% means the control-plane change itself slowed the two-tier
    hot path."""
    try:
        with open(snapshot_path) as f:
            snap = json.load(f)
        snap_speedup = float(snap["speedup_vs_seed"])
    except (OSError, KeyError, ValueError):
        return {"fast_path_overhead_pct": None, "fast_path_within_5pct": True}
    overhead = (snap_speedup / max(out["speedup_vs_seed"], 1e-9) - 1.0) * 100.0
    return {
        "snapshot_speedup_vs_seed": snap_speedup,
        "fast_path_overhead_pct": round(overhead, 2),
        "fast_path_within_5pct": overhead < 5.0,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=6)
    ap.add_argument("--out", default=os.path.join(_REPO_ROOT, "BENCH_des.json"))
    ap.add_argument("--smoke", action="store_true",
                    help="quick 2-rep timing print (no file write) — the CI "
                         "gating-lane smoke")
    ap.add_argument("--sweep-1k", action="store_true",
                    help="run only the 1024-cell grid A/B/C (numpy + pallas "
                         "batched vs scalar pool; no file write) and gate on "
                         "the <=8%% cross-lane bound — the CI slow-lane job")
    ap.add_argument("--obs", action="store_true",
                    help="run only the observability overhead A/B (tracing/"
                         "histograms/profiler on vs off; no file write) and "
                         "gate on <10%% overhead + bit-identical outcomes — "
                         "the CI gating-lane obs smoke")
    args = ap.parse_args()
    snapshot = os.path.join(_REPO_ROOT, "BENCH_des.json")
    if args.smoke:
        out = {"bench": "des_fast_path_smoke", **bench_ab(2)}
        out.update(check_fast_path_overhead(out, snapshot))
        print(json.dumps(out, indent=2))
        return
    if args.obs:
        out = {"bench": "des_obs_overhead", **bench_obs(max(args.reps, 3))}
        print(json.dumps(out, indent=2))
        assert out["obs_bit_identical"], (
            "observability instrumentation perturbed the simulation "
            "(bandwidth/latency/ToR counters differ with tracing on)"
        )
        assert out["obs_within_10pct"], (
            f"observability instrumentation added {out['obs_overhead_pct']}% "
            "wall time on the co-run config (>10% budget)"
        )
        return
    if args.sweep_1k:
        out = {"bench": "des_sweep_1k", **bench_sweep_1k()}
        print(json.dumps(out, indent=2))
        assert out["lanes_within_gate"], (
            f"batched lanes off the scalar DES on the 1024-cell grid "
            f"(numpy {out['numpy_lane']}, pallas {out['pallas_lane']})"
        )
        if not out["batched_speedup_ge_5x"]:
            print("WARNING: batched lane below the 5x acceptance bar on "
                  "the 1024-cell grid (noisy machine, or a regression)")
        return
    out = {"bench": "des_fast_path", **bench_ab(args.reps), **check_goldens()}
    out.update(check_fast_path_overhead(out, snapshot))
    out["sweep_lanes"] = bench_sweep_lanes()
    out["sweep_1k"] = bench_sweep_1k()
    out["observability"] = bench_obs(args.reps)
    print(json.dumps(out, indent=2))
    if out["speedup_vs_seed"] < 2.0:
        print("WARNING: speedup below the 2x acceptance bar "
              "(noisy machine, or a fast-path regression)")
    if not out["sweep_lanes"]["batched_speedup_ge_5x"]:
        print("WARNING: batched lane below the 5x acceptance bar vs the "
              "scalar pool (noisy machine, or a batched-lane regression)")
    if not out["sweep_1k"]["batched_speedup_ge_5x"]:
        print("WARNING: batched lane below the 5x acceptance bar on the "
              "1024-cell grid (noisy machine, or a batched-lane regression)")
    assert out["sweep_1k"]["lanes_within_gate"], (
        "batched lanes off the scalar DES on the 1024-cell grid "
        "(decision flips or aligned-p95 out of bounds); snapshot left "
        "untouched"
    )
    assert out["observability"]["obs_bit_identical"], (
        "observability instrumentation perturbed the simulation; "
        "snapshot left untouched"
    )
    assert out["observability"]["obs_within_10pct"], (
        f"observability added {out['observability']['obs_overhead_pct']}% "
        "on the co-run config (>10% budget); snapshot left untouched"
    )
    # Gate BEFORE writing: a failing run must not replace the snapshot it
    # was compared against (the baseline would self-ratchet downward).
    assert out["fast_path_within_5pct"], (
        f"per-tier contract added {out['fast_path_overhead_pct']}% on the "
        "two-tier fast path vs the BENCH_des.json snapshot (>5% budget); "
        "snapshot left untouched"
    )
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")


if __name__ == "__main__":
    main()

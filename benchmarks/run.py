# One function per paper table. Print ``name,us_per_call,derived`` CSV.

from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (
        fig2_tiering,
        fig3_bandwidth,
        fig4_latency,
        fig5_corun,
        fig7_llc,
        fig8_sync,
        fig9_service,
        fig10_miku,
        fig11_llm,
        fig13_spark,
        fig14_kv,
        roofline_table,
    )
    from benchmarks.common import emit

    modules = [
        fig2_tiering, fig3_bandwidth, fig4_latency, fig5_corun, fig7_llc,
        fig8_sync, fig9_service, fig10_miku, fig11_llm, fig13_spark,
        fig14_kv, roofline_table,
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for mod in modules:
        if only and only not in mod.__name__:
            continue
        try:
            emit(mod.run())
        except Exception as ex:  # keep the harness going; failures visible
            emit([(mod.__name__, 0.0, f"ERROR:{type(ex).__name__}:{ex}")])


if __name__ == "__main__":
    main()

# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
# Usage:
#   PYTHONPATH=src python benchmarks/run.py [filter] [--jobs N]
#
# ``--jobs N`` runs the figure modules concurrently in a process pool (each
# module's sweep is itself a batch of independent sims; figure-level
# parallelism composes with REPRO_SWEEP_PROCS for the in-module sweeps).
# Output order is deterministic (module order) either way.

from __future__ import annotations

import argparse
import os
import sys
from concurrent.futures import ProcessPoolExecutor

# Allow `python benchmarks/run.py` as well as `python -m benchmarks.run`.
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _REPO_ROOT not in sys.path:
    sys.path.insert(0, _REPO_ROOT)

_MODULE_NAMES = [
    "fig2_tiering",
    "fig3_bandwidth",
    "fig4_latency",
    "fig5_corun",
    "fig7_llc",
    "fig8_sync",
    "fig9_service",
    "fig10_miku",
    "fig11_llm",
    "fig13_spark",
    "fig14_kv",
    "roofline_table",
]


def _run_module(name: str) -> list:
    """Worker entry: import + run one figure module, exceptions as rows."""
    import importlib

    try:
        mod = importlib.import_module(f"benchmarks.{name}")
        return list(mod.run())
    except Exception as ex:  # keep the harness going; failures visible
        return [(name, 0.0, f"ERROR:{type(ex).__name__}:{ex}")]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("only", nargs="?", default=None,
                    help="substring filter on figure module names")
    ap.add_argument("--jobs", type=int, default=1,
                    help="process-pool width for running figure modules")
    args = ap.parse_args()

    from benchmarks.common import emit

    names = [n for n in _MODULE_NAMES if not args.only or args.only in n]
    print("name,us_per_call,derived")
    if args.jobs > 1 and len(names) > 1:
        with ProcessPoolExecutor(max_workers=min(args.jobs, len(names))) as pool:
            for rows in pool.map(_run_module, names):
                emit(rows)
    else:
        for name in names:
            emit(_run_module(name))


if __name__ == "__main__":
    main()

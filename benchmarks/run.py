# The benchmark/experiment harness, two modes:
#
#   1. Scenario mode — run any registry-declared scenario as a result table:
#        PYTHONPATH=src python benchmarks/run.py --list
#        PYTHONPATH=src python benchmarks/run.py --scenario fig3_bandwidth \
#            --set platform=A --set threads=1,16 --format csv
#        PYTHONPATH=src python benchmarks/run.py --scenario corun3_switch \
#            --set op=load --format json
#      --trace NAME additionally records the ControlLoop's per-window,
#      per-tier decision telemetry and writes NAME.csv (or .json, per
#      --format) plus NAME.trace.json next to each other:
#        PYTHONPATH=src python benchmarks/run.py --scenario corun3_pertier \
#            --set law=pertier --trace corun3_pertier
#      --perfetto NAME samples request-lifecycle span chains and writes
#      NAME.perfetto.json (Chrome trace-event JSON; see
#      docs/observability.md):
#        PYTHONPATH=src python benchmarks/run.py \
#            --scenario fabric_spine_congestion --set law=peredge \
#            --perfetto spine
#
#   2. Figure mode (legacy) — run the paper-figure modules, printing
#      ``name,us_per_call,derived`` CSV:
#        PYTHONPATH=src python benchmarks/run.py [filter] [--jobs N]
#
# ``--jobs N`` runs the figure modules concurrently in a process pool (each
# module's sweep is itself a batch of independent sims; figure-level
# parallelism composes with REPRO_SWEEP_PROCS for the in-module sweeps).
# Output order is deterministic (module order) either way.
#
# The figure-module list is *derived from the scenario registry* (each
# scenario names the benchmarks module that presents it), so the registry
# and the module list cannot drift; roofline_table is the one non-scenario
# module and is appended explicitly.

from __future__ import annotations

import argparse
import os
import sys
from concurrent.futures import ProcessPoolExecutor

# Allow `python benchmarks/run.py` as well as `python -m benchmarks.run`.
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _REPO_ROOT not in sys.path:
    sys.path.insert(0, _REPO_ROOT)
_SRC = os.path.join(_REPO_ROOT, "src")
if os.path.isdir(_SRC) and _SRC not in sys.path:
    sys.path.insert(0, _SRC)

_EXTRA_MODULES = ["roofline_table"]  # presentation-only, not a scenario


def _module_names() -> list:
    """Figure modules in registry declaration order + non-scenario extras."""
    from repro.scenarios import all_scenarios

    mods = []
    for sc in all_scenarios():
        if sc.module and sc.module not in mods:
            mods.append(sc.module)
    mods.extend(_EXTRA_MODULES)
    return mods


def _run_module(name: str) -> list:
    """Worker entry: import + run one figure module, exceptions as rows."""
    import importlib

    try:
        mod = importlib.import_module(f"benchmarks.{name}")
        return list(mod.run())
    except Exception as ex:  # keep the harness going; failures visible
        return [(name, 0.0, f"ERROR:{type(ex).__name__}:{ex}")]


def _fmt_default(v) -> str:
    from repro.scenarios.spec import format_default

    return format_default(v)


def _list_scenarios(fmt: str = "csv") -> None:
    from repro.scenarios import all_scenarios

    if fmt == "md":
        # The generated docs/scenarios.md payload (CI regenerates the file
        # from this output and fails on diff — keep it deterministic).
        from repro.scenarios.catalog import catalog_md

        print(catalog_md(), end="")
        return
    for sc in all_scenarios():
        grid = []
        for a in sc.axes:
            mark = "*" if a.is_grid else ""
            grid.append(f"{a.name}{mark}={_fmt_default(a.default)}")
        figure = f" [{sc.figure}]" if sc.figure else ""
        slow = " (slow)" if sc.slow else ""
        print(f"{sc.name}{figure}{slow} — {sc.title}")
        if grid:
            print(f"    axes: {', '.join(grid)}")
        if sc.metrics:
            print(f"    metrics: {', '.join(m.name for m in sc.metrics)}")


def _write_perfetto(table, name: str) -> None:
    """Flatten per-cell span payloads into one Chrome trace-event file."""
    import json

    from repro.obs.trace import to_chrome

    records = []
    for ci, cell in enumerate(table.request_traces or []):
        for job in cell["jobs"]:
            payload = job["trace"]
            if not payload:
                continue
            for rec in payload["requests"]:
                # One trace process per (cell, job, workload) so grid cells
                # stay distinguishable in the Perfetto UI.
                records.append({
                    **rec,
                    "workload":
                        f"cell{ci}/job{job['job']}/{rec['workload']}",
                })
    path = f"{name}.perfetto.json"
    if not records:
        # No request retired while sampled (e.g. a horizon shorter than one
        # service time): an empty trace would just confuse Perfetto — say
        # so instead of writing it.
        print(f"no request-lifecycle spans were recorded, skipping {path}")
        return
    with open(path, "w") as f:
        json.dump(to_chrome(records), f, indent=1)
        f.write("\n")
    print(f"wrote {path} ({len(records)} traced requests)")


def _run_scenario(name: str, set_args: list, fmt: str, jobs: int,
                  trace: str = "", lane: str = "", perfetto: str = "",
                  profile: bool = False) -> None:
    import json

    from repro.scenarios import (
        UnknownScenarioError,
        get,
        parse_set_args,
        run_scenario,
    )

    try:
        sc = get(name)
    except UnknownScenarioError as ex:
        # Typos exit non-zero with near-miss suggestions instead of a
        # traceback (the message lists every registered name too).
        print(f"error: {ex}", file=sys.stderr)
        sys.exit(2)
    overrides = parse_set_args(sc, set_args)
    table = run_scenario(sc, overrides, processes=jobs if jobs > 1 else None,
                         trace=bool(trace), lane=lane or None,
                         perfetto=bool(perfetto), profile=profile)
    if perfetto:
        _write_perfetto(table, perfetto)
    if profile:
        print(f"profile: {json.dumps(table.meta.get('profile', {}))}",
              file=sys.stderr)
    if lane:
        # Lane routing summary on stderr so csv/json stdout stays clean.
        print(f"lane: {json.dumps(table.meta)}", file=sys.stderr)
        for reason, n in table.meta.get(
                "fallback_reason_counts", {}).items():
            print(f"lane fallback [{n} job(s)]: {reason}", file=sys.stderr)
    if fmt == "json":
        out = table.to_json()
    else:
        out = table.to_csv()
    if trace:
        # Result table and per-window decision telemetry side by side.
        table_path = f"{trace}.{'json' if fmt == 'json' else 'csv'}"
        trace_path = f"{trace}.trace.json"
        with open(table_path, "w") as f:
            f.write(out if out.endswith("\n") else out + "\n")
        has_windows = table.traces and any(
            j["windows"] for t in table.traces for j in t["jobs"]
        )
        if has_windows:
            with open(trace_path, "w") as f:
                json.dump({"scenario": table.scenario, "params": table.params,
                           "traces": table.traces}, f, indent=2)
                f.write("\n")
            print(f"wrote {table_path} and {trace_path}")
        else:
            # No job recorded any control-plane windows (e.g. the horizon is
            # shorter than one window): an empty trace file would just break
            # downstream tooling — say so instead.
            print(f"wrote {table_path}; no per-window telemetry was "
                  f"recorded (no job completed a control window), "
                  f"skipping {trace_path}")
    print(out, end="" if fmt != "json" else "\n")


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Run registry scenarios or paper-figure modules."
    )
    ap.add_argument("only", nargs="?", default=None,
                    help="substring filter on figure module names")
    ap.add_argument("--jobs", type=int, default=1,
                    help="process-pool width (figure modules, or the "
                         "scenario's sweep)")
    ap.add_argument("--list", action="store_true", dest="list_scenarios",
                    help="list registered scenarios and exit")
    ap.add_argument("--scenario", default=None, metavar="NAME",
                    help="run one registered scenario as a result table")
    ap.add_argument("--set", action="append", default=[], metavar="AXIS=VAL",
                    dest="set_args",
                    help="override a scenario axis (repeatable; comma "
                         "lists make grids)")
    ap.add_argument("--format", choices=("csv", "json", "md"), default="csv",
                    help="scenario result-table format (md: with --list, "
                         "the generated docs/scenarios.md catalog)")
    ap.add_argument("--trace", default="", metavar="NAME",
                    help="with --scenario: record per-window per-tier "
                         "decision telemetry; write NAME.csv/.json and "
                         "NAME.trace.json")
    ap.add_argument("--perfetto", default="", metavar="NAME",
                    help="with --scenario: sample request-lifecycle span "
                         "chains (every 16th admission, scalar DES) and "
                         "write NAME.perfetto.json — Chrome trace-event "
                         "JSON loadable in Perfetto/chrome://tracing")
    ap.add_argument("--profile", action="store_true",
                    help="with --scenario: print a wall-clock phase "
                         "profile (plan/sweep/reduce + per-job event-loop "
                         "split) and the observability counters to stderr")
    ap.add_argument("--lane", choices=("scalar", "batched"), default="",
                    help="with --scenario: sweep execution lane (batched = "
                         "vectorized repro.memsim.batched; inexpressible "
                         "jobs fall back to the scalar DES)")
    ap.add_argument("--sanitize", action="store_true",
                    help="run with the runtime sanitizer "
                         "(repro.analysis): per-window invariant checks "
                         "on every simulation; violations raise.  Forces "
                         "the scalar DES.  Equivalent to REPRO_SANITIZE=1.")
    args = ap.parse_args()

    if args.sanitize:
        # The env switch (not a SimJob field) so every sim in the process —
        # scenario sweeps, figure modules, TransferQueue benchmarks — is
        # sanitized, including ones built in pool workers, which inherit
        # the environment.
        os.environ["REPRO_SANITIZE"] = "1"

    if args.list_scenarios:
        if args.format == "json":
            ap.error("--list supports --format md (markdown catalog) or "
                     "the default text listing")
        _list_scenarios(args.format)
        return
    if args.format == "md":
        ap.error("--format md is only valid with --list")
    if args.scenario:
        _run_scenario(args.scenario, args.set_args, args.format, args.jobs,
                      args.trace, args.lane, args.perfetto, args.profile)
        return
    if args.set_args:
        ap.error("--set requires --scenario")
    if args.trace:
        ap.error("--trace requires --scenario")
    if args.lane:
        ap.error("--lane requires --scenario")
    if args.perfetto:
        ap.error("--perfetto requires --scenario")
    if args.profile:
        ap.error("--profile requires --scenario")

    from benchmarks.common import emit

    names = [n for n in _module_names()
             if not args.only or args.only in n]
    print("name,us_per_call,derived")
    if args.jobs > 1 and len(names) > 1:
        with ProcessPoolExecutor(max_workers=min(args.jobs, len(names))) as pool:
            for rows in pool.map(_run_module, names):
                emit(rows)
    else:
        for name in names:
            emit(_run_module(name))


if __name__ == "__main__":
    main()

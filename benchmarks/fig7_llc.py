"""Fig. 7 — shim over the ``fig7_llc`` scenario."""

from repro.scenarios import run_scenario

from benchmarks.common import Row, timed


def run() -> list:
    rows: list[Row] = []
    for wss in (60.0, 120.0):
        def one(wss=wss):
            out = run_scenario(
                "fig7_llc", {"platform": "A", "wss_mb": (wss,)}
            ).rows
            return ";".join(
                f"ddr_share={r['ddr_llc_share']:.2f}:ddr={r['ddr_gbps']:.0f}"
                f",cxl={r['cxl_gbps']:.0f}" for r in out
            )
        rows.append(timed(f"fig7_llc_wss{int(wss)}MB", one))
    return rows

"""Fig. 7 — LLC partition sweep (CAT analogue) under tiered co-run."""

from repro.core.device_model import platform_a
from repro.memsim.runner import llc_partition_sweep

from benchmarks.common import Row, timed


def run() -> list:
    p = platform_a()
    rows: list[Row] = []
    for wss in (60.0, 120.0):
        def one(wss=wss):
            out = llc_partition_sweep(p, wss)
            return ";".join(
                f"ddr_share={r['ddr_llc_share']:.2f}:ddr={r['ddr_gbps']:.0f}"
                f",cxl={r['cxl_gbps']:.0f}" for r in out
            )
        rows.append(timed(f"fig7_llc_wss{int(wss)}MB", one))
    return rows

"""Fig. 2 — aggregated bandwidth of tiered-memory management schemes."""

from repro.core.device_model import platform_a
from repro.core.littles_law import OpClass
from repro.memsim.runner import tiering_schemes

from benchmarks.common import Row, timed


def run() -> list:
    rows: list[Row] = []
    p = platform_a()
    for op in OpClass:
        def one(op=op):
            r = tiering_schemes(p, op)
            return (
                f"ideal={r['ideal_combined']:.0f}GBps;"
                f"native={r['native']:.0f};interleave={r['interleave']:.0f};"
                f"os_managed={r['os_managed']:.0f}"
            )
        rows.append(timed(f"fig2_tiering_{op.value}", one))
    return rows

"""Fig. 2 — shim over the ``fig2_tiering`` scenario."""

from repro.scenarios import run_scenario

from benchmarks.common import Row, timed


def run() -> list:
    rows: list[Row] = []
    for op in ("load", "store", "nt_store"):
        def one(op=op):
            (r,) = run_scenario("fig2_tiering",
                                {"platform": "A", "op": op}).rows
            return (
                f"ideal={r['ideal_combined']:.0f}GBps;"
                f"native={r['native']:.0f};interleave={r['interleave']:.0f};"
                f"os_managed={r['os_managed']:.0f}"
            )
        rows.append(timed(f"fig2_tiering_{op}", one))
    return rows

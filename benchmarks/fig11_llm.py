"""Fig. 11/12 — co-located LLM serving: HBM-resident vs host-tier-resident
instance, DataRacing vs MIKU vs Opt.  Real jitted decode steps (reduced
llama31 config), tier timing from the transfer-path model (DESIGN.md §2)."""

import jax

from repro.configs import get_arch
from repro.core.controller import MikuConfig, MikuController
from repro.core.littles_law import EstimatorConfig
from repro.models.transformer import TransformerLM
from repro.serving.engine import (
    EngineConfig,
    Request,
    ServingEngine,
    TieredServingCluster,
)

from benchmarks.common import Row, timed

_N_REQ_FAST = 48
_N_REQ_SLOW = 16
_NEW_TOKENS = 24
_CHUNKS = 64


def _mk(name, placement, cfg, params, n_req):
    e = ServingEngine(
        EngineConfig(name=name, model=cfg, max_slots=4, max_len=96,
                     placement=placement, stream_chunks=_CHUNKS),
        params,
    )
    for i in range(n_req):
        e.submit(Request(rid=i, prompt=list(range(1, 9)),
                         max_new_tokens=_NEW_TOKENS))
    return e


def _controller(chunk_service_ns: float) -> MikuController:
    est = EstimatorConfig(
        t_fast=1.2e3,
        slow_read_threshold=8 * chunk_service_ns,
        ewma=0.5,
        min_window_inserts=4,
        min_slow_inserts=1,
    )
    return MikuController(MikuConfig(levels=(1, 2, 4, 8)), est)


def run() -> list:
    cfg = get_arch("llama31-8b").smoke
    model = TransformerLM(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    probe = _mk("probe", "host", cfg, params, 0)
    chunk_service = probe.param_bytes / _CHUNKS / 16.0  # host link B/ns

    results = {}

    def opt():
        a = TieredServingCluster(
            [_mk("hbm", "device", cfg, params, _N_REQ_FAST)]).run(20000)
        b = TieredServingCluster(
            [_mk("host", "host", cfg, params, _N_REQ_SLOW)]).run(20000)
        results["opt"] = (a["hbm"]["tokens_per_s"], b["host"]["tokens_per_s"])
        return (f"hbm={results['opt'][0]:.0f}tok/s;"
                f"host={results['opt'][1]:.0f}tok/s")

    def racing():
        r = TieredServingCluster(
            [_mk("hbm", "device", cfg, params, _N_REQ_FAST),
             _mk("host", "host", cfg, params, _N_REQ_SLOW)]
        ).run(40000)
        results["racing"] = (r["hbm"]["tokens_per_s"],
                             r["host"]["tokens_per_s"])
        o = results["opt"]
        return (f"hbm={100*r['hbm']['tokens_per_s']/o[0]:.0f}%of_opt;"
                f"host={100*r['host']['tokens_per_s']/o[1]:.0f}%of_opt")

    def miku():
        ctl = _controller(chunk_service)
        r = TieredServingCluster(
            [_mk("hbm", "device", cfg, params, _N_REQ_FAST),
             _mk("host", "host", cfg, params, _N_REQ_SLOW)],
            controller=ctl, window_ns=3e4,
        ).run(40000)
        results["miku"] = (r["hbm"]["tokens_per_s"], r["host"]["tokens_per_s"])
        o = results["opt"]
        restricted = sum(1 for d in ctl.decisions if d.restricted)
        return (f"hbm={100*r['hbm']['tokens_per_s']/o[0]:.0f}%of_opt;"
                f"host={100*r['host']['tokens_per_s']/o[1]:.0f}%of_opt;"
                f"restricted_windows={restricted}/{len(ctl.decisions)}")

    return [timed("fig11_llm_opt", opt),
            timed("fig11_llm_dataracing", racing),
            timed("fig11_llm_miku", miku)]

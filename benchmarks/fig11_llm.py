"""Fig. 11/12 — shim over the ``fig11_llm`` scenario (real jitted decode
steps on the serving engine; the one non-DES figure)."""

from repro.scenarios import run_scenario

from benchmarks.common import timed


def run() -> list:
    rows = {}

    def compute():
        for r in run_scenario("fig11_llm").rows:
            rows[r["variant"]] = r

    def opt():
        compute()  # one scenario run covers all three variants
        r = rows["opt"]
        return (f"hbm={r['hbm_tokens_per_s']:.0f}tok/s;"
                f"host={r['host_tokens_per_s']:.0f}tok/s")

    def racing():
        r = rows["racing"]
        return (f"hbm={r['hbm_pct_of_opt']:.0f}%of_opt;"
                f"host={r['host_pct_of_opt']:.0f}%of_opt")

    def miku():
        r = rows["miku"]
        return (f"hbm={r['hbm_pct_of_opt']:.0f}%of_opt;"
                f"host={r['host_pct_of_opt']:.0f}%of_opt;"
                f"restricted_windows={r['restricted_windows']}/{r['windows']}")

    return [timed("fig11_llm_opt", opt),
            timed("fig11_llm_dataracing", racing),
            timed("fig11_llm_miku", miku)]

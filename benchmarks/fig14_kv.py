"""Fig. 14 — concurrent-hashmap (YCSB) analog: read:write ratio sweep.
Random accesses with modest MLP (pointer-chasing-ish), racing vs MIKU."""

from repro.core.des import TieredMemorySim, WorkloadSpec
from repro.core.device_model import platform_a
from repro.core.littles_law import OpClass
from repro.memsim.calibration import default_miku

from benchmarks.common import Row, timed

_SIM_NS = 300_000.0


def _kv(name, tier, ratio, managed):
    # ratio r reads per write: split cores between get (load) and insert
    # (store) streams; hash probing limits MLP.
    total = 16
    readers = round(total * ratio / (ratio + 1))
    wls = []
    # gets probe hash chains (shallow MLP); inserts are RMW bursts with
    # deeper outstanding writes — the paper: "a higher ratio of inserts ...
    # results in a greater memory workload, allowing MIKU to demonstrate
    # its effectiveness more".
    if readers:
        wls.append(WorkloadSpec(name=f"{name}-get", op=OpClass.LOAD, tier=tier,
                                n_cores=readers, mlp=32, miku_managed=managed))
    if total - readers:
        wls.append(WorkloadSpec(name=f"{name}-ins", op=OpClass.STORE, tier=tier,
                                n_cores=total - readers, mlp=128,
                                miku_managed=managed))
    return wls


def run() -> list:
    p = platform_a()
    rows: list[Row] = []
    for ratio in (0, 1, 4):
        def one(ratio=ratio):
            ddr = _kv("ddr", "ddr", ratio, False)
            cxl = _kv("cxl", "cxl", ratio, True)
            race = TieredMemorySim(p, ddr + cxl).run(_SIM_NS)
            miku = TieredMemorySim(p, ddr + cxl, controller=default_miku(p),
                                   window_ns=10_000.0).run(_SIM_NS)
            race_ddr = sum(race.bandwidth(w.name) for w in ddr)
            miku_ddr = sum(miku.bandwidth(w.name) for w in ddr)
            miku_cxl = sum(miku.bandwidth(w.name) for w in cxl)
            gain = miku_ddr / max(race_ddr, 1e-9)
            return (f"racing_ddr={race_ddr:.0f}GBps;miku_ddr={miku_ddr:.0f}"
                    f"(x{gain:.2f});miku_cxl={miku_cxl:.0f}")
        rows.append(timed(f"fig14_kv_r{ratio}w1", one))
    return rows

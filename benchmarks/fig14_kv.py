"""Fig. 14 — shim over the ``fig14_kv`` scenario."""

from repro.scenarios import run_scenario

from benchmarks.common import Row, timed


def run() -> list:
    rows: list[Row] = []
    for ratio in (0, 1, 4):
        def one(ratio=ratio):
            (r,) = run_scenario(
                "fig14_kv", {"platform": "A", "ratio": (ratio,)}
            ).rows
            return (f"racing_ddr={r['racing_ddr_gbps']:.0f}GBps;"
                    f"miku_ddr={r['miku_ddr_gbps']:.0f}"
                    f"(x{r['miku_gain']:.2f});"
                    f"miku_cxl={r['miku_cxl_gbps']:.0f}")
        rows.append(timed(f"fig14_kv_r{ratio}w1", one))
    return rows

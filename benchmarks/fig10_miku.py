"""Fig. 10 — shim over the ``fig10_miku`` scenario."""

from repro.scenarios import run_scenario

from benchmarks.common import Row, timed


def run() -> list:
    rows: list[Row] = []
    for op in ("load", "store", "nt_store"):
        def one(op=op):
            (r,) = run_scenario("fig10_miku",
                                {"platform": "A", "op": op}).rows
            return (
                f"racing_ddr={r['racing_ddr']:.0f}GBps;"
                f"miku_ddr={r['miku_ddr']:.0f}"
                f"({100*r['miku_ddr']/max(r['opt_ddr'],1e-9):.0f}%of_opt);"
                f"miku_cxl={r['miku_cxl']:.0f}"
                f"({100*r['miku_cxl']/max(r['opt_cxl'],1e-9):.0f}%of_opt)"
            )
        rows.append(timed(f"fig10_miku_{op}", one))
    return rows

"""Fig. 10 — MIKU vs DataRacing vs Opt on alternating micro-benchmarks."""

from repro.core.device_model import platform_a
from repro.core.littles_law import OpClass
from repro.memsim.runner import miku_comparison

from benchmarks.common import Row, timed


def run() -> list:
    p = platform_a()
    rows: list[Row] = []
    for op in OpClass:
        def one(op=op):
            r = miku_comparison(p, op)
            return (
                f"racing_ddr={r.racing_ddr:.0f}GBps;miku_ddr={r.miku_ddr:.0f}"
                f"({100*r.miku_ddr/max(r.opt_ddr,1e-9):.0f}%of_opt);"
                f"miku_cxl={r.miku_cxl:.0f}"
                f"({100*r.miku_cxl/max(r.opt_cxl,1e-9):.0f}%of_opt)"
            )
        rows.append(timed(f"fig10_miku_{op.value}", one))
    return rows

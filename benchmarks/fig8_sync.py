"""Fig. 8 — shim over the ``fig8_sync`` scenario."""

from repro.scenarios import run_scenario

from benchmarks.common import timed


def run() -> list:
    def one():
        out = run_scenario("fig8_sync", {"platform": "A"}).rows
        return ";".join(
            f"{r['bg_tier']}/{r['bg_threads']}bg={r['cas_latency_ns']:.0f}ns"
            for r in out
        )

    return [timed("fig8_sync_interference", one)]

"""Fig. 8 — cross-core CAS latency under DDR vs CXL background traffic."""

from repro.core.device_model import platform_a
from repro.memsim.runner import sync_interference

from benchmarks.common import Row, timed


def run() -> list:
    p = platform_a()

    def one():
        out = sync_interference(p)
        return ";".join(
            f"{r['bg_tier']}/{r['bg_threads']}bg={r['cas_latency_ns']:.0f}ns"
            for r in out
        )

    return [timed("fig8_sync_interference", one)]

# Seed (pre-refactor) DES snapshot — benchmark baseline ONLY.
#
# This is the object-per-request DES exactly as it shipped in the seed
# commit (6755b8d), kept so benchmarks/bench_des.py can measure the
# fast-path rewrite against its true baseline *interleaved on the same
# machine* (container CPU throttling makes cross-run wall-clock
# comparisons unreliable).  Do not import this from library code and do
# not maintain it: it is a frozen measurement artifact.
"""Discrete-event simulation of the cores → IRQ → ToR → {DDR, CXL} pipeline.

This is the simulated testbed standing in for the paper's two hardware
platforms (no CXL hardware exists in this container; the TPU is likewise only
a compile target).  It models exactly the structures the paper's root-cause
analysis identifies (§4.2):

  * **Cores** with bounded memory-level parallelism (MLP: LFB/superqueue +
    prefetcher slots) issue requests in a closed loop; ``lat-test`` style
    workloads are dependent (MLP=1, pointer chasing), ``bw-test`` style
    workloads keep MLP slots full.
  * **IRQ** — the CHA ingress queue: a *shared, finite, FIFO* staging queue.
    Only its head may dispatch (head-of-line blocking); when full it
    back-pressures all cores indiscriminately — the paper's "CHA throttles
    both DDR and CXL requests from upstream components".
  * **ToR** — the Table of Requests: a finite shared pool of tracking
    entries.  A request holds its entry from dispatch until data return, so
    entry residency *is* the memory service time (queue wait at the device +
    service + bus flight).  Slow-tier requests with 8-10x residency
    monopolize the pool — the unfair-queuing mechanism.
  * **Devices** — DDR group / CXL group per :mod:`repro.core.device_model`:
    ``c`` deterministic servers + unbounded internal queue (requests wait
    *while holding ToR entries*).
  * **LLC** — an optional station in front of the devices; hits are serviced
    fast but still consume ToR entries (paper §4.3), so LLC effectiveness
    degrades under slow-tier backlog.  Capacity partitioning (Intel CAT
    analogue) sets per-workload hit rates.

MIKU attaches as a window callback: every ``window_ns`` the simulator hands
the controller per-tier :class:`TierCounters` deltas and applies the returned
concurrency/rate decision to slow-tier-bound workloads — identical in shape
to how the real MIKU samples uncore counters once per second.
"""

from __future__ import annotations

import dataclasses
import heapq
import random
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.controller import Decision, MikuController
from repro.core.device_model import DeviceModel, PlatformModel
from repro.core.littles_law import OpClass, TierCounters

# Event kinds (heap payloads are (time, seq, kind, arg)).
_EV_COMPLETE = 0  # service slot frees (device done); data starts return flight
_EV_PHASE = 1
_EV_WINDOW = 2
_EV_TOKEN = 3
_EV_RETIRE = 4  # data returned: ToR entry frees, core slot recycles


@dataclasses.dataclass
class WorkloadSpec:
    """One co-running benchmark instance (a group of identical cores).

    ``tier`` may be a single tier or a phase schedule (``phases`` overrides
    ``tier`` with (duration_ns, tier) pairs, cycled — the paper's
    alternating-every-100 s micro-benchmark, time-scaled).  ``dependent``
    marks pointer-chasing (lat-test): MLP is forced to 1.  ``sync`` marks the
    lat-share CAS loop: requests are coherence ops serviced at the LLC/CHA
    with exclusive-line bouncing.  ``wss_mb`` with a finite ``llc_alloc_mb``
    yields an LLC hit probability of min(1, alloc/wss) (CAT partitioning).
    """

    name: str
    op: OpClass
    tier: str  # "ddr" | "cxl"
    n_cores: int
    #: Outstanding cachelines per core, *including* L2-prefetcher stream
    #: depth — bw-test's sequential streams keep the prefetchers saturated,
    #: which is what lets a 16-thread group's aggregate demand exceed the
    #: shared ToR pool (the monopolization precondition, §4.2).
    mlp: int = 160
    dependent: bool = False
    sync: bool = False
    wss_mb: float = 32768.0
    llc_alloc_mb: float = 0.0
    phases: Optional[Sequence[Tuple[float, str]]] = None
    miku_managed: bool = True  # slow-tier workloads MIKU may throttle
    #: Software page-interleaving across tiers: fraction of requests sent to
    #: DDR (the rest go to CXL).  Overrides ``tier`` when set (Fig. 1/2
    #: "Interleaving" scheme; Linux weighted interleaving).
    ddr_fraction: Optional[float] = None

    def effective_mlp(self, granularity: int = 1) -> int:
        """Outstanding *simulated requests* per core (macro-request units)."""
        if self.dependent or self.sync:
            return 1
        return max(1, self.mlp // granularity)


@dataclasses.dataclass
class WorkloadStats:
    completed: int = 0
    bytes: float = 0.0
    latency_sum: float = 0.0
    latency_count: int = 0
    latency_samples: List[float] = dataclasses.field(default_factory=list)
    # timeline of (t_ns, bytes_completed_in_bucket) for bandwidth-over-time
    timeline: List[Tuple[float, float]] = dataclasses.field(default_factory=list)

    def mean_latency_ns(self) -> float:
        return self.latency_sum / max(1, self.latency_count)

    def percentile_ns(self, q: float) -> float:
        if not self.latency_samples:
            return 0.0
        xs = sorted(self.latency_samples)
        idx = min(len(xs) - 1, int(q * len(xs)))
        return xs[idx]

    def bandwidth_gbps(self, sim_ns: float) -> float:
        return self.bytes / sim_ns  # B/ns == GB/s


class _Station:
    """c deterministic servers + FIFO queue.  Queue entries hold ToR slots."""

    __slots__ = ("name", "slots", "busy", "queue")

    def __init__(self, name: str, slots: int):
        self.name = name
        self.slots = slots
        self.busy = 0
        self.queue: deque = deque()

    @property
    def backlog(self) -> int:
        return len(self.queue)


class _Request:
    __slots__ = ("wl", "core", "op", "tier", "station", "t_issue", "t_tor", "service")

    def __init__(self, wl: int, core: int, op: OpClass, tier: str):
        self.wl = wl
        self.core = core
        self.op = op
        self.tier = tier
        self.station = ""
        self.t_issue = 0.0
        self.t_tor = 0.0
        self.service = 0.0


@dataclasses.dataclass
class SimResult:
    sim_ns: float
    stats: Dict[str, WorkloadStats]
    tier_counters: Dict[str, TierCounters]
    tor_peak: int
    tor_occupancy_integral: float  # entry-ns, all tiers
    tor_inserts: int
    decisions: List[Decision]
    per_tier_occupancy_integral: Dict[str, float]

    def bandwidth(self, name: str) -> float:
        return self.stats[name].bandwidth_gbps(self.sim_ns)

    def total_bandwidth(self, tier: Optional[str] = None) -> float:
        return sum(s.bandwidth_gbps(self.sim_ns) for s in self.stats.values())

    @property
    def tor_avg_latency_ns(self) -> float:
        """Occupancy/Inserts — exactly the paper's ToR-derived service time."""
        return self.tor_occupancy_integral / max(1, self.tor_inserts)


class TieredMemorySim:
    """The DES engine.  Deterministic given a seed."""

    def __init__(
        self,
        platform: PlatformModel,
        workloads: Sequence[WorkloadSpec],
        *,
        seed: int = 0,
        granularity: int = 4,
        window_ns: float = 20_000.0,
        controller: Optional[MikuController] = None,
        latency_sample_every: int = 97,
    ):
        self.platform = platform
        self.workloads = list(workloads)
        self.rng = random.Random(seed)
        # Granularity batches `granularity` cachelines per simulated request:
        # identical bandwidth & queueing structure, ~granularity x fewer
        # events.  Latency-sensitive (dependent/sync) workloads always run at
        # single-access granularity.
        self.granularity = max(1, granularity)
        self.window_ns = window_ns
        self.controller = controller
        self.latency_sample_every = latency_sample_every

        self.now = 0.0
        self._seq = 0
        self._heap: List[Tuple[float, int, int, object]] = []

        # Stations.
        self.ddr = _Station("ddr", platform.ddr.total_slots)
        self.cxl = _Station("cxl", platform.cxl.total_slots)
        self.llc = _Station("llc", platform.llc_slots)
        self._stations = {"ddr": self.ddr, "cxl": self.cxl, "llc": self.llc}

        # Shared queues.  Platform capacities are in cachelines; one simulated
        # macro-request covers `granularity` cachelines, so scale down.
        self.tor_capacity = max(1, platform.tor_entries // self.granularity)
        self.tor_used = 0
        self.tor_peak = 0
        self.irq: deque = deque()
        self.irq_capacity = max(1, platform.irq_entries // self.granularity)
        # Round-robin arbitration order over every (workload, core) pair:
        # real cores are open-loop instruction streams that re-attempt IRQ
        # insertion every cycle; the IRQ arbitrates fairly *per core*, so the
        # IRQ inflow mix reflects core counts — not completion rates.  This
        # is precisely what makes the paper's collapse: DDR and CXL cores
        # inject at the same rate while CXL entries retire ~10x slower.
        self._rr: List[Tuple[int, int]] = []
        self._rr_ptr = 0

        # Per-core issue bookkeeping.
        self._core_out: List[List[int]] = []  # outstanding per (wl, core)
        self._phase_tier: List[str] = []
        self._phase_idx: List[int] = []

        # Throttle state per workload (set by MIKU decisions).
        self._max_cores: List[Optional[int]] = [None] * len(self.workloads)
        self._rate: List[float] = [1.0] * len(self.workloads)
        self._tokens: List[float] = [0.0] * len(self.workloads)
        self._last_refill: List[float] = [0.0] * len(self.workloads)
        self._token_wait: List[bool] = [False] * len(self.workloads)

        # Accounting.
        self.stats: Dict[str, WorkloadStats] = {
            w.name: WorkloadStats() for w in self.workloads
        }
        self.tier_counters = {"ddr": TierCounters(), "cxl": TierCounters()}
        self._window_marks = {
            "ddr": self.tier_counters["ddr"].snapshot(),
            "cxl": self.tier_counters["cxl"].snapshot(),
        }
        self.tor_occupancy_integral = 0.0
        self._per_tier_occ = {"ddr": 0.0, "cxl": 0.0}
        self.tor_inserts = 0
        self._last_occ_t = 0.0
        self.decisions: List[Decision] = []
        self._tier_inflight = {"ddr": 0, "cxl": 0}
        self._timeline_bucket_ns = window_ns
        self._timeline_acc: Dict[str, float] = {w.name: 0.0 for w in self.workloads}
        self._timeline_next = self._timeline_bucket_ns

        for wi, w in enumerate(self.workloads):
            self._core_out.append([0] * w.n_cores)
            self._phase_idx.append(0)
            self._phase_tier.append(w.phases[0][1] if w.phases else w.tier)
            for core in range(w.n_cores):
                self._rr.append((wi, core))

    # -- event plumbing -----------------------------------------------------
    def _push(self, t: float, kind: int, arg: object) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (t, self._seq, kind, arg))

    def _advance_occupancy(self) -> None:
        dt = self.now - self._last_occ_t
        if dt > 0:
            self.tor_occupancy_integral += self.tor_used * dt
            self._per_tier_occ["ddr"] += self._tier_inflight["ddr"] * dt
            self._per_tier_occ["cxl"] += self._tier_inflight["cxl"] * dt
            self._last_occ_t = self.now

    # -- issue path ----------------------------------------------------------
    def _request_bytes(self, wl: WorkloadSpec, device: DeviceModel) -> int:
        g = 1 if (wl.dependent or wl.sync) else self.granularity
        return device.access_bytes * g

    def _touches_slow(self, wi: int) -> bool:
        """Does this workload currently generate slow-tier traffic?  (MIKU
        identifies CXL-accessing threads via sampled physical addresses; the
        simulator knows placement exactly — DESIGN.md §2.)"""
        w = self.workloads[wi]
        if w.ddr_fraction is not None:
            return w.ddr_fraction < 1.0
        return self._phase_tier[wi] == "cxl"

    def _core_active(self, wi: int, core: int) -> bool:
        limit = self._max_cores[wi]
        w = self.workloads[wi]
        if not w.miku_managed or not self._touches_slow(wi):
            limit = None  # decisions apply to slow-tier-bound workloads only
        return limit is None or core < limit

    def _take_token(self, wi: int, cost: float) -> bool:
        """Token bucket in request-cost units; rate_factor scales refill."""
        rate = self._rate[wi]
        w = self.workloads[wi]
        if rate >= 1.0 or not w.miku_managed or not self._touches_slow(wi):
            return True
        dt = self.now - self._last_refill[wi]
        self._tokens[wi] = min(cost * 4.0, self._tokens[wi] + dt * rate)
        self._last_refill[wi] = self.now
        if self._tokens[wi] >= cost:
            self._tokens[wi] -= cost
            return True
        if not self._token_wait[wi]:
            self._token_wait[wi] = True
            wait = (cost - self._tokens[wi]) / max(rate, 1e-6)
            self._push(self.now + wait, _EV_TOKEN, wi)
        return False

    def _issue_one(self, wi: int, core: int) -> bool:
        """Try to issue exactly one request from (wi, core) into the IRQ."""
        w = self.workloads[wi]
        if self._core_out[wi][core] >= w.effective_mlp(self.granularity):
            return False
        if not self._core_active(wi, core):
            return False
        tier = self._phase_tier[wi]
        if w.ddr_fraction is not None:
            tier = "ddr" if self.rng.random() < w.ddr_fraction else "cxl"
        device = self.platform.device_for(tier)
        cost = device.service_ns(w.op) * (
            1 if (w.dependent or w.sync) else self.granularity
        )
        if not self._take_token(wi, cost):
            return False
        req = _Request(wi, core, w.op, tier)
        req.t_issue = self.now
        self._core_out[wi][core] += 1
        self.irq.append(req)
        return True

    def _fill_irq(self) -> None:
        """Round-robin core arbitration into free IRQ space (open-loop issue
        pressure: every core with MLP headroom re-attempts continuously)."""
        n = len(self._rr)
        misses = 0
        while len(self.irq) < self.irq_capacity and misses < n:
            wi, core = self._rr[self._rr_ptr]
            self._rr_ptr = (self._rr_ptr + 1) % n
            if self._issue_one(wi, core):
                misses = 0
            else:
                misses += 1

    def _refill_issue(self, wi: int) -> None:
        del wi
        self._fill_irq()
        self._pump()

    # -- IRQ -> ToR -> station ------------------------------------------------
    def _pump(self) -> None:
        """Admit IRQ heads into the ToR while entries are free (HoL FIFO),
        letting cores refill freed IRQ space round-robin."""
        while self.irq and self.tor_used < self.tor_capacity:
            req = self.irq.popleft()
            self._advance_occupancy()
            self.tor_used += 1
            self.tor_peak = max(self.tor_peak, self.tor_used)
            self.tor_inserts += 1
            self._tier_inflight[req.tier] += 1
            req.t_tor = self.now
            self._route(req)
            if len(self.irq) < self.irq_capacity:
                self._fill_irq()

    def _route(self, req: _Request) -> None:
        w = self.workloads[req.wl]
        if w.sync:
            station = self.llc
            req.service = self.platform.llc_service_ns * 2.0  # line bounce RFO
            req.station = "llc"
        else:
            hit = False
            if w.llc_alloc_mb > 0:
                p_hit = min(1.0, w.llc_alloc_mb / max(w.wss_mb, 1e-9))
                hit = self.rng.random() < p_hit
            if hit:
                station = self.llc
                req.service = self.platform.llc_service_ns * (
                    1 if (w.dependent or w.sync) else self.granularity
                )
                req.station = "llc"
            else:
                device = self.platform.device_for(req.tier)
                station = self._stations[req.tier]
                g = 1 if (w.dependent or w.sync) else self.granularity
                req.service = device.service_ns(w.op) * g
                req.station = req.tier
        if station.busy < station.slots:
            station.busy += 1
            self._start_service(req)
        else:
            station.queue.append(req)

    def _start_service(self, req: _Request) -> None:
        # The device slot is held for the service time only; the return
        # flight (pipeline) happens off the slot.  The ToR entry, however, is
        # held until the data returns (_EV_RETIRE) — this is why slow-tier
        # residency at the ToR explodes under load while device throughput
        # stays flat (paper §4.2 "service time rises but remains stable").
        self._push(self.now + req.service, _EV_COMPLETE, req)

    def _complete(self, req: _Request) -> None:
        station = self._stations[req.station]
        # Free the server; pull the next queued request.
        if station.queue:
            nxt = station.queue.popleft()
            self._start_service(nxt)
        else:
            station.busy -= 1
        pipeline = (
            0.0
            if req.station == "llc"
            else self.platform.device_for(req.tier).pipeline_ns
        )
        if pipeline > 0.0:
            self._push(self.now + pipeline, _EV_RETIRE, req)
        else:
            self._retire(req)

    def _retire(self, req: _Request) -> None:
        # Free the ToR entry.
        self._advance_occupancy()
        self.tor_used -= 1
        self._tier_inflight[req.tier] -= 1
        residency = self.now - req.t_tor
        if req.station != "llc":
            self.tier_counters[req.tier].record(req.op, residency)
        # Account workload stats.
        w = self.workloads[req.wl]
        st = self.stats[w.name]
        st.completed += 1
        device = self.platform.device_for(req.tier)
        nbytes = float(self._request_bytes(w, device))
        st.bytes += nbytes
        self._timeline_acc[w.name] += nbytes
        latency = self.now - req.t_issue
        st.latency_sum += latency
        st.latency_count += 1
        if st.latency_count % self.latency_sample_every == 0:
            st.latency_samples.append(latency)
        # Core slot freed: reissue (round-robin with everyone else), admit.
        self._core_out[req.wl][req.core] -= 1
        self._fill_irq()
        self._pump()

    # -- phases / windows ------------------------------------------------------
    def _schedule_phases(self) -> None:
        for wi, w in enumerate(self.workloads):
            if w.phases:
                dur, _ = w.phases[0]
                self._push(dur, _EV_PHASE, wi)

    def _phase_flip(self, wi: int) -> None:
        w = self.workloads[wi]
        assert w.phases is not None
        self._phase_idx[wi] = (self._phase_idx[wi] + 1) % len(w.phases)
        dur, tier = w.phases[self._phase_idx[wi]]
        self._phase_tier[wi] = tier
        self._push(self.now + dur, _EV_PHASE, wi)
        self._refill_issue(wi)

    def _window(self) -> None:
        if self.controller is not None:
            deltas = {}
            for tier in ("ddr", "cxl"):
                snap = self.tier_counters[tier]
                deltas[tier] = snap.delta(self._window_marks[tier])
                self._window_marks[tier] = snap.snapshot()
            decision = self.controller.window(deltas["ddr"], deltas["cxl"])
            self.decisions.append(decision)
            for wi, w in enumerate(self.workloads):
                if not w.miku_managed:
                    continue
                self._max_cores[wi] = decision.max_concurrency
                self._rate[wi] = decision.rate_factor
                self._refill_issue(wi)
        # Flush bandwidth timeline buckets.
        while self.now >= self._timeline_next:
            for w in self.workloads:
                self.stats[w.name].timeline.append(
                    (self._timeline_next, self._timeline_acc[w.name])
                )
                self._timeline_acc[w.name] = 0.0
            self._timeline_next += self._timeline_bucket_ns
        self._push(self.now + self.window_ns, _EV_WINDOW, None)

    # -- run --------------------------------------------------------------------
    def run(self, sim_ns: float) -> SimResult:
        self._schedule_phases()
        self._push(self.window_ns, _EV_WINDOW, None)
        self._fill_irq()
        self._pump()
        while self._heap:
            t, _, kind, arg = heapq.heappop(self._heap)
            if t > sim_ns:
                break
            self.now = t
            if kind == _EV_COMPLETE:
                self._complete(arg)  # type: ignore[arg-type]
            elif kind == _EV_RETIRE:
                self._retire(arg)  # type: ignore[arg-type]
            elif kind == _EV_PHASE:
                self._phase_flip(arg)  # type: ignore[arg-type]
            elif kind == _EV_WINDOW:
                self._window()
            elif kind == _EV_TOKEN:
                wi = arg  # type: ignore[assignment]
                self._token_wait[wi] = False
                self._refill_issue(wi)
        self.now = sim_ns
        self._advance_occupancy()
        return SimResult(
            sim_ns=sim_ns,
            stats=self.stats,
            tier_counters=self.tier_counters,
            tor_peak=self.tor_peak,
            tor_occupancy_integral=self.tor_occupancy_integral,
            tor_inserts=self.tor_inserts,
            decisions=self.decisions,
            per_tier_occupancy_integral=dict(self._per_tier_occ),
        )


# ---------------------------------------------------------------------------
# Convenience runners used by memsim + benchmarks.
# ---------------------------------------------------------------------------


def run_bw_test(
    platform: PlatformModel,
    *,
    op: OpClass,
    tier: str,
    n_threads: int,
    sim_ns: float = 150_000.0,
    mlp: int = 160,
    seed: int = 0,
) -> SimResult:
    wl = WorkloadSpec(
        name=f"bw-{tier}-{op.value}", op=op, tier=tier, n_cores=n_threads, mlp=mlp
    )
    sim = TieredMemorySim(platform, [wl], seed=seed)
    return sim.run(sim_ns)


def run_lat_test(
    platform: PlatformModel,
    *,
    op: OpClass,
    tier: str,
    n_threads: int = 1,
    sim_ns: float = 300_000.0,
    seed: int = 0,
) -> SimResult:
    wl = WorkloadSpec(
        name=f"lat-{tier}-{op.value}",
        op=op,
        tier=tier,
        n_cores=n_threads,
        dependent=True,
    )
    sim = TieredMemorySim(platform, [wl], seed=seed, granularity=1)
    return sim.run(sim_ns)


def run_corun(
    platform: PlatformModel,
    *,
    op: OpClass,
    n_threads: int = 16,
    sim_ns: float = 200_000.0,
    controller: Optional[MikuController] = None,
    mlp: int = 160,
    seed: int = 0,
    window_ns: float = 10_000.0,
) -> SimResult:
    """Two co-running bw-tests: one on DDR, one on CXL (paper Fig. 5/10)."""
    wls = [
        WorkloadSpec(
            name="ddr", op=op, tier="ddr", n_cores=n_threads, mlp=mlp,
            miku_managed=False,
        ),
        WorkloadSpec(name="cxl", op=op, tier="cxl", n_cores=n_threads, mlp=mlp),
    ]
    sim = TieredMemorySim(
        platform, wls, seed=seed, controller=controller, window_ns=window_ns
    )
    return sim.run(sim_ns)

"""Shared benchmark plumbing: every figure module exposes ``run() ->
list[(name, us_per_call, derived)]`` and run.py prints the CSV."""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

Row = Tuple[str, float, str]


def timed(name: str, fn: Callable[[], str]) -> Row:
    t0 = time.time()
    derived = fn()
    us = (time.time() - t0) * 1e6
    return (name, us, derived)


def emit(rows: List[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")

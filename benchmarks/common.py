"""Shared benchmark plumbing: every figure module exposes ``run() ->
list[(name, us_per_call, derived)]`` and run.py prints the CSV."""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

Row = Tuple[str, float, str]


def timed(name: str, fn: Callable[[], str]) -> Row:
    # perf_counter: monotonic and high-resolution — time.time() can step
    # under NTP and quantizes coarsely on some platforms.
    t0 = time.perf_counter()
    derived = fn()
    us = (time.perf_counter() - t0) * 1e6
    return (name, us, derived)


def emit(rows: List[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")

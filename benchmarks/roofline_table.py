"""Roofline table over the dry-run sweep (assignment §Roofline).

Reads ``dryrun_baseline.json`` (written by ``repro.launch.dryrun``) if
present — re-running the 88-cell sweep inside the benchmark harness would
take ~20 min — and emits one row per cell with the three terms, the
dominant bottleneck, MODEL_FLOPS ratio, and roofline fraction.
"""

from __future__ import annotations

import json
import os
from typing import Optional

from repro.configs import SHAPES, get_arch
from repro.roofline.analysis import V5E, model_flops

from benchmarks.common import Row

_DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..",
                             "dryrun_baseline.json")


def load_cells(path: Optional[str] = None) -> list:
    path = path or _DEFAULT_PATH
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def cell_row(cell: dict) -> Optional[str]:
    if not cell.get("ok"):
        return None
    spec = get_arch(cell["arch"])
    shape = SHAPES[cell["shape"]]
    n_dev = 512 if "2x16" in cell["mesh"] else 256
    flops = cell["flops_per_device"]
    nbytes = cell.get("bytes_min_per_device") or cell["bytes_per_device"]
    coll = sum(cell["collective_bytes"].values())
    compute_s = flops / V5E.peak_flops
    memory_s = nbytes / V5E.hbm_bw
    coll_s = coll / V5E.link_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(spec, shape)
    ratio = mf / max(flops * n_dev, 1e-9)
    ideal = mf / (n_dev * V5E.peak_flops)
    frac = ideal / max(max(terms.values()), 1e-30)
    return (
        f"compute={compute_s:.3g}s;memory={memory_s:.3g}s;"
        f"collective={coll_s:.3g}s;dominant={dominant};"
        f"useful_ratio={ratio:.3f};roofline_frac={frac:.3f}"
    )


def run() -> list:
    rows: list[Row] = []
    cells = load_cells()
    if not cells:
        return [("roofline_table", 0.0,
                 "dryrun_baseline.json missing: run python -m repro.launch.dryrun")]
    for cell in cells:
        if cell["mesh"] != "pod16x16":
            continue  # roofline table is single-pod per the assignment
        derived = cell_row(cell)
        if derived is None:
            continue
        rows.append(
            (f"roofline_{cell['arch']}_{cell['shape']}", 0.0, derived)
        )
    return rows

"""Fig. 9 — memory service time vs thread count (MIKU's detection signal),
cross-validated against the JAX MVA solver."""

from repro.core.device_model import platform_a
from repro.core.littles_law import OpClass
from repro.core.mva import analyze
from repro.memsim.runner import service_time_curve

from benchmarks.common import Row, timed


def run() -> list:
    p = platform_a()

    def one():
        out = service_time_curve(p)
        return ";".join(
            f"{r['tier']}/{r['threads']}t={r['service_time_ns']:.0f}ns"
            for r in out
        )

    def mva():
        parts = []
        for n in (1, 4, 16):
            r = analyze(p, OpClass.LOAD, fast_threads=0, slow_threads=n)
            parts.append(f"cxl/{n}t={float(r.residency_slow):.0f}ns")
        return ";".join(parts)

    return [timed("fig9_service_time_des", one),
            timed("fig9_service_time_mva", mva)]

"""Fig. 9 — shim over the ``fig9_service`` scenario, cross-validated
against the JAX MVA solver."""

from repro.core.device_model import platform_a
from repro.core.littles_law import OpClass
from repro.core.mva import analyze
from repro.scenarios import run_scenario

from benchmarks.common import timed


def run() -> list:
    def one():
        out = run_scenario("fig9_service", {"platform": "A"}).rows
        return ";".join(
            f"{r['tier']}/{r['threads']}t={r['service_time_ns']:.0f}ns"
            for r in out
        )

    def mva():
        p = platform_a()
        parts = []
        for n in (1, 4, 16):
            r = analyze(p, OpClass.LOAD, fast_threads=0, slow_threads=n)
            parts.append(f"cxl/{n}t={float(r.residency_slow):.0f}ns")
        return ";".join(parts)

    return [timed("fig9_service_time_des", one),
            timed("fig9_service_time_mva", mva)]

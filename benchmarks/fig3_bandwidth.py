"""Fig. 3 — shim over the ``fig3_bandwidth`` scenario."""

from repro.scenarios import run_scenario

from benchmarks.common import Row, timed


def run() -> list:
    rows: list[Row] = []
    for label in ("A", "A-1to1", "B", "B-1to1"):
        def one(label=label):
            out = run_scenario("fig3_bandwidth", {"platform": label}).rows
            parts = [
                f"{r['op']}/{r['tier']}/{r['threads']}t={r['bandwidth_gbps']:.1f}"
                for r in out
            ]
            return ";".join(parts)
        rows.append(timed(f"fig3_bw_platform{label}", one))
    return rows

"""Fig. 3 — DDR vs CXL single/multi-thread bandwidth, default and 1:1."""

from repro.core.device_model import platform_a, platform_b
from repro.memsim.runner import bandwidth_matrix

from benchmarks.common import Row, timed


def run() -> list:
    rows: list[Row] = []
    for label, p in (
        ("A", platform_a()), ("A-1to1", platform_a(1, 1)),
        ("B", platform_b()), ("B-1to1", platform_b(1, 1)),
    ):
        def one(p=p):
            out = bandwidth_matrix(p)
            parts = [
                f"{r['op']}/{r['tier']}/{r['threads']}t={r['bandwidth_gbps']:.1f}"
                for r in out
            ]
            return ";".join(parts)
        rows.append(timed(f"fig3_bw_platform{label}", one))
    return rows

"""Fig. 4 — average and tail (p99) latency, DDR vs CXL, thread sweep."""

from repro.core.device_model import platform_a
from repro.memsim.runner import latency_matrix

from benchmarks.common import Row, timed


def run() -> list:
    p = platform_a()

    def one():
        out = latency_matrix(p)
        return ";".join(
            f"{r['tier']}/{r['threads']}t:avg={r['avg_ns']:.0f}ns,p99={r['p99_ns']:.0f}"
            for r in out
        )

    return [timed("fig4_latency_platformA", one)]

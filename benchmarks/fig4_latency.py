"""Fig. 4 — shim over the ``fig4_latency`` scenario."""

from repro.scenarios import run_scenario

from benchmarks.common import timed


def run() -> list:
    def one():
        out = run_scenario("fig4_latency", {"platform": "A"}).rows
        return ";".join(
            f"{r['tier']}/{r['threads']}t:avg={r['avg_ns']:.0f}ns,p99={r['p99_ns']:.0f}"
            for r in out
        )

    return [timed("fig4_latency_platformA", one)]

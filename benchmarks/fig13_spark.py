"""Fig. 13 — shim over the ``fig13_spark`` scenario."""

from repro.scenarios import run_scenario

from benchmarks.common import timed


def run() -> list:
    rows = {}

    def compute():
        for r in run_scenario("fig13_spark", {"platform": "A"}).rows:
            rows[r["variant"]] = r

    def opt():
        compute()  # one scenario run covers all three variants
        r = rows["opt"]
        return f"ddr={r['ddr_gbps']:.0f}GBps;cxl={r['cxl_gbps']:.0f}GBps"

    def racing():
        r = rows["racing"]
        return (f"ddr={r['ddr_pct_of_opt']:.0f}%of_opt;"
                f"cxl={r['cxl_pct_of_opt']:.0f}%of_opt")

    def miku():
        r = rows["miku"]
        return (f"ddr={r['ddr_pct_of_opt']:.0f}%of_opt(paper:>=81%);"
                f"cxl={r['cxl_pct_of_opt']:.0f}%of_opt")

    return [timed("fig13_spark_opt", opt),
            timed("fig13_spark_dataracing", racing),
            timed("fig13_spark_miku", miku)]

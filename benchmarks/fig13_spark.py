"""Fig. 13 — big-data (Spark/TPC-H) analog: shuffle-heavy mixed read/write
phases co-running on DDR and CXL, racing vs MIKU vs opt."""

from repro.core.des import TieredMemorySim, WorkloadSpec
from repro.core.device_model import platform_a
from repro.core.littles_law import OpClass
from repro.memsim.calibration import default_miku

from benchmarks.common import Row, timed

_SIM_NS = 400_000.0


def _spark_workload(name, tier, miku_managed=True):
    # Query pipeline: scan (loads) -> shuffle write (stores) -> reduce
    # (loads), cycled; phases model per-query behaviour.
    # 16 executor threads with deep prefetched scan/shuffle streams — the
    # memory pressure that makes the paper's Spark runs collapse to 30%.
    return WorkloadSpec(
        name=name, op=OpClass.STORE, tier=tier, n_cores=16, mlp=160,
        phases=[(60_000.0, tier)] * 1, miku_managed=miku_managed,
    )


def run() -> list:
    p = platform_a()

    def opt():
        a = TieredMemorySim(p, [_spark_workload("ddr", "ddr", False)]).run(_SIM_NS)
        b = TieredMemorySim(p, [_spark_workload("cxl", "cxl")]).run(_SIM_NS)
        run.opt = (a.bandwidth("ddr"), b.bandwidth("cxl"))  # type: ignore
        return f"ddr={run.opt[0]:.0f}GBps;cxl={run.opt[1]:.0f}GBps"

    def racing():
        r = TieredMemorySim(
            p, [_spark_workload("ddr", "ddr", False), _spark_workload("cxl", "cxl")]
        ).run(_SIM_NS)
        o = run.opt
        return (f"ddr={100*r.bandwidth('ddr')/o[0]:.0f}%of_opt;"
                f"cxl={100*r.bandwidth('cxl')/o[1]:.0f}%of_opt")

    def miku():
        r = TieredMemorySim(
            p, [_spark_workload("ddr", "ddr", False), _spark_workload("cxl", "cxl")],
            controller=default_miku(p), window_ns=10_000.0,
        ).run(_SIM_NS)
        o = run.opt
        return (f"ddr={100*r.bandwidth('ddr')/o[0]:.0f}%of_opt(paper:>=81%);"
                f"cxl={100*r.bandwidth('cxl')/o[1]:.0f}%of_opt")

    return [timed("fig13_spark_opt", opt),
            timed("fig13_spark_dataracing", racing),
            timed("fig13_spark_miku", miku)]

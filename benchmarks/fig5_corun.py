"""Fig. 5 + 6 — co-run bandwidth collapse and ToR accounting (the paper's
headline: up to 81-89% DDR loss; ToR-insert/bandwidth Pearson r=0.998)."""

from repro.core.device_model import platform_a, platform_b
from repro.memsim.runner import corun_matrix, tor_insert_bandwidth_correlation

from benchmarks.common import Row, timed


def run() -> list:
    rows: list[Row] = []
    for label, p in (("A", platform_a()), ("B", platform_b())):
        def one(p=p):
            out = corun_matrix(p)
            return ";".join(
                f"{r['op']}:ddr_loss={r['ddr_loss_pct']:.1f}%"
                f",t_cxl={r['t_cxl_corun_ns']:.0f}ns"
                for r in out
            )
        rows.append(timed(f"fig5_corun_platform{label}", one))

    def corr():
        r = tor_insert_bandwidth_correlation(platform_a())
        return f"pearson_r={r:.4f}(paper:0.998)"

    rows.append(timed("fig6_tor_insert_bw_correlation", corr))
    return rows

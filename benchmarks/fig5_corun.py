"""Fig. 5 + 6 — shim over the ``fig5_corun`` + ``fig6_tor_correlation``
scenarios (the paper's headline: up to 81-89% DDR loss; ToR-insert /
bandwidth Pearson r=0.998)."""

from repro.scenarios import run_scenario

from benchmarks.common import Row, timed


def run() -> list:
    rows: list[Row] = []
    for label in ("A", "B"):
        def one(label=label):
            out = run_scenario("fig5_corun", {"platform": label}).rows
            return ";".join(
                f"{r['op']}:ddr_loss={r['ddr_loss_pct']:.1f}%"
                f",t_cxl={r['t_cxl_corun_ns']:.0f}ns"
                for r in out
            )
        rows.append(timed(f"fig5_corun_platform{label}", one))

    def corr():
        (r,) = run_scenario("fig6_tor_correlation", {"platform": "A"}).rows
        return f"pearson_r={r['pearson_r']:.4f}(paper:0.998)"

    rows.append(timed("fig6_tor_insert_bw_correlation", corr))
    return rows
